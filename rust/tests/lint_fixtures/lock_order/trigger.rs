// Golden fixture: two hierarchy violations against the fixture config
// (`state` = rank 1, `slots` = rank 2).  Expected findings (both
// unsuppressed):
//   line 9  — rank inversion (acquired rank 1 while holding rank 2)
//   line 15 — same-class nesting (self-deadlock risk)

pub fn inverted(this: &Shards) -> usize {
    let g = this.slots.lock();
    let h = this.state.lock();
    g.len() + h.len()
}

pub fn doubled(a: &Shards, b: &Shards) -> usize {
    let g = a.state.lock();
    let h = b.state.lock();
    g.len() + h.len()
}
