//! Quickstart: load the engine, decode a few prompts with DAPD, print
//! the results.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the 60-second tour of the public API: `Engine` -> `XlaModel`
//! (a compiled AOT artifact) -> `decode_batch` with a `DecodeConfig`.

use anyhow::Result;
use dapd::decode::{decode_batch, DecodeConfig, Method};
use dapd::runtime::{Engine, ForwardModel};
use dapd::workload::{scorer, EvalSet};

fn main() -> Result<()> {
    let engine = Engine::load(std::path::Path::new("artifacts"))?;

    // A compiled forward pass: sim-llada, batch 4, full generation window.
    let model = engine.model_for("sim-llada", 4, engine.meta.gen_len)?;
    println!(
        "model: seq_len={} prompt_len={} gen_len={} vocab={}",
        model.seq_len(),
        model.prompt_len(),
        model.gen_len(),
        model.vocab()
    );

    // Four structured-output prompts from the exported eval set.
    let set = EvalSet::load(&engine.meta, "struct")?.take(4);
    let prompts: Vec<Vec<i32>> = set.instances.iter().map(|i| i.prompt.clone()).collect();

    // Dependency-Aware Parallel Decoding, default hyperparameters.
    let cfg = DecodeConfig::new(Method::DapdStaged);
    let outcomes = decode_batch(&model, &prompts, &cfg)?;

    for (inst, out) in set.instances.iter().zip(&outcomes) {
        let score = scorer::score("struct", &out.gen, &inst.expect, &inst.spec);
        println!(
            "\nprompt: {}\ngen ({} steps, score {score}): {}",
            engine.meta.detok(&inst.prompt),
            out.steps,
            engine.meta.detok(&out.gen)
        );
    }

    // Compare against token-by-token decoding on the same prompts.
    let base = decode_batch(&model, &prompts, &DecodeConfig::new(Method::Original))?;
    let dapd_steps: f64 =
        outcomes.iter().map(|o| o.steps as f64).sum::<f64>() / outcomes.len() as f64;
    let base_steps: f64 = base.iter().map(|o| o.steps as f64).sum::<f64>() / base.len() as f64;
    println!(
        "\nDAPD: {dapd_steps:.1} steps/sample vs Original: {base_steps:.1} \
         ({:.2}x speedup)",
        base_steps / dapd_steps
    );
    Ok(())
}
