//! Chaos smoke: drives a fault-injected serving stack next to a clean
//! reference and proves supervised recovery end to end over real TCP:
//!
//!   1. reference — each distinct prompt's generation is fetched once
//!      from a fault-free server;
//!   2. chaos — a concurrent wave against the faulted server must lose
//!      zero requests: every response arrives, typed, and every accepted
//!      generation is token-identical to the clean reference;
//!   3. SLO — accepted-request p99 stays bounded (`DAPD_CHAOS_SLO_MS`)
//!      even while forwards error, hang, and panic underneath;
//!   4. needles — `{"prometheus": true}` on the faulted server exposes
//!      the recovery counters (`dapd_faults_injected`, `dapd_retries`,
//!      `dapd_watchdog_reaps`, `dapd_worker_restarts`,
//!      `dapd_breaker_state`, `dapd_degraded_steps`) with the injection
//!      and retry totals the run must have produced;
//!   5. drain — both servers drain in-band with zero loss.
//!
//!     cargo run --release --example chaos_smoke             # self-boot
//!     cargo run --release --example chaos_smoke -- \
//!         --addr 127.0.0.1:7094 --ref-addr 127.0.0.1:7093
//!
//! With `--addr`/`--ref-addr`, drives externally booted `dapd serve
//! --mock` processes (the CI chaos-smoke job does this; the faulted one
//! gets `--fault-spec ... --forward-timeout-ms 250 --max-retries 4`).
//! The default plan's seed keeps consecutive-failure runs at three or
//! less on both replicas, so every fault is recoverable inside the
//! retry budget and a lost or divergent response is a real bug.  Knobs:
//!
//!   --total N / --concurrency N   chaos wave shape (40 / 8)
//!   DAPD_CHAOS_SLO_MS    p99 SLO for accepted requests (default 20000)
//!   DAPD_CHAOS_JSON=f    write the outcome/latency summary to `f`

use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use dapd::coordinator::{Coordinator, CoordinatorHandle, PoolOptions};
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::{FaultPlan, MockModel, ModelPool};
use dapd::server::{Client, Server};
use dapd::util::args::Args;
use dapd::util::json::Json;
use dapd::util::stats::Summary;

const PROMPT_LEN: usize = 28;

/// The CI chaos plan: ~18% of forwards fault inside the first 400 calls
/// per replica (transient errors, NaN rows, latency spikes), one hang
/// (watchdog food) and one panic (respawn food) per replica.
const CHAOS_SPEC: &str = "seed=9;error=0.15;nan=0.05;latency=0.1:5;until=400;hang_at=3;panic_at=9";

fn prompts(k: usize) -> Vec<Vec<i32>> {
    (0..k)
        .map(|i| {
            (0..PROMPT_LEN)
                .map(|j| (2 + (i * 7 + j) % 88) as i32)
                .collect()
        })
        .collect()
}

enum Outcome {
    /// served in full, token-identical to the reference
    Accepted { latency_ms: f64 },
    /// served in full but diverged from the reference — never tolerated
    Diverged(String),
    /// any refusal or transport failure — never tolerated here (the
    /// verified plan recovers every fault inside the retry budget, and
    /// the chaos wave stays far below the admission caps)
    Lost(String),
}

fn one_request(addr: &str, prompt: &[i32], want: &[i64]) -> Outcome {
    let t0 = Instant::now();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => return Outcome::Lost(format!("connect: {e:#}")),
    };
    let mut req = Json::obj();
    req.set(
        "prompt",
        prompt.iter().map(|&t| t as i64).collect::<Vec<i64>>().into(),
    );
    let resp = match client.roundtrip(&req) {
        Ok(r) => r,
        Err(e) => return Outcome::Lost(format!("roundtrip: {e:#}")),
    };
    if resp.get("ok").as_bool() != Some(true) {
        return Outcome::Lost(format!("refused: {}", resp.dump()));
    }
    let gen = resp.get("gen").to_i64_vec().unwrap_or_default();
    if gen != want {
        return Outcome::Diverged(format!(
            "generation diverged from the clean reference\n  chaos {gen:?}\n  ref   {want:?}"
        ));
    }
    Outcome::Accepted {
        latency_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// Fetch the clean generation for each prompt from the reference server.
fn fetch_reference(addr: &str, prompts: &[Vec<i32>]) -> Result<Vec<Vec<i64>>> {
    let mut client = Client::connect(addr)?;
    prompts
        .iter()
        .map(|p| {
            let mut req = Json::obj();
            req.set(
                "prompt",
                p.iter().map(|&t| t as i64).collect::<Vec<i64>>().into(),
            );
            let r = client.roundtrip(&req)?;
            if r.get("ok").as_bool() != Some(true) {
                bail!("reference server refused a prompt: {}", r.dump());
            }
            let gen = r.get("gen").to_i64_vec().unwrap_or_default();
            if gen.is_empty() {
                bail!("reference reply without tokens: {}", r.dump());
            }
            Ok(gen)
        })
        .collect()
}

/// `name{worker="all"}` sample value from an exposition text.
fn series_value(text: &str, name: &str) -> Option<f64> {
    let prefix = format!("{name}{{worker=\"all\"}} ");
    text.lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .and_then(|v| v.trim().parse().ok())
}

/// Phase 4: the recovery counters must be exposed and must show the
/// injection, retry, reap and respawn activity the verified plan
/// guarantees for a run of this size.
fn check_needles(addr: &str, total: usize) -> Result<()> {
    let mut client = Client::connect(addr)?;
    let mut preq = Json::obj();
    preq.set("prometheus", true.into());
    let p = client.roundtrip(&preq)?;
    if p.get("ok").as_bool() != Some(true) {
        bail!("needles: prometheus request refused: {}", p.dump());
    }
    let text = p
        .get("text")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("prometheus reply without text"))?;
    // every recovery series must exist, gauges included
    for needle in [
        "# TYPE dapd_faults_injected counter",
        "# TYPE dapd_retries counter",
        "# TYPE dapd_watchdog_reaps counter",
        "# TYPE dapd_worker_restarts counter",
        "# TYPE dapd_degraded_steps counter",
        "# TYPE dapd_breaker_state gauge",
        "# TYPE dapd_degraded gauge",
    ] {
        if !text.contains(needle) {
            bail!("needles: exposition missing `{needle}`");
        }
    }
    // value floors: ~28% of forwards fault inside the 400-call horizon,
    // the hang fires on the first session a replica runs, the panic
    // once a replica passes its tenth call — all guaranteed at this
    // run size (the floor caps at 100 so an oversized --total cannot
    // outrun the `until=400` horizon)
    for (name, floor) in [
        ("dapd_faults_injected", total.min(100) as f64),
        ("dapd_retries", 1.0),
        ("dapd_watchdog_reaps", 1.0),
        ("dapd_worker_restarts", 1.0),
    ] {
        let got = series_value(text, name)
            .ok_or_else(|| anyhow::anyhow!("needles: no aggregate sample for {name}"))?;
        if got < floor {
            bail!("needles: {name} = {got}, expected >= {floor}");
        }
    }
    println!(
        "phase 4 needles: injected={} retries={} reaps={} restarts={} degraded_steps={}",
        series_value(text, "dapd_faults_injected").unwrap_or(0.0),
        series_value(text, "dapd_retries").unwrap_or(0.0),
        series_value(text, "dapd_watchdog_reaps").unwrap_or(0.0),
        series_value(text, "dapd_worker_restarts").unwrap_or(0.0),
        series_value(text, "dapd_degraded_steps").unwrap_or(0.0),
    );
    Ok(())
}

fn drain(addr: &str) -> Result<()> {
    let mut admin = Client::connect(addr)?;
    let mut dreq = Json::obj();
    dreq.set("drain", true.into());
    let ack = admin.roundtrip(&dreq)?;
    if ack.get("draining").as_bool() != Some(true) {
        bail!("drain request not acknowledged: {}", ack.dump());
    }
    Ok(())
}

struct LocalServer {
    server: std::thread::JoinHandle<()>,
    pool: CoordinatorHandle,
    coord: Coordinator,
}

fn boot_local(fault: Option<FaultPlan>) -> Result<(String, LocalServer)> {
    let pool = ModelPool::mock(MockModel::new(4, 68, PROMPT_LEN, 92));
    let opts = PoolOptions {
        workers: 2,
        batch_wait: Duration::from_millis(2),
        forward_timeout: if fault.is_some() {
            Duration::from_millis(250)
        } else {
            Duration::ZERO
        },
        max_retries: 4,
        fault,
        ..PoolOptions::default()
    };
    let (coord, handles) = Coordinator::start_pool(&pool, &opts)?;
    let server = Server::bind(
        "127.0.0.1:0",
        coord.clone(),
        DecodeConfig::new(Method::DapdStaged),
    )?;
    let addr = server.local_addr()?.to_string();
    let sh = std::thread::spawn(move || server.run().unwrap());
    Ok((
        addr,
        LocalServer {
            server: sh,
            pool: handles,
            coord,
        },
    ))
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let total = args.usize_or("total", 40);
    let concurrency = args.usize_or("concurrency", 8).max(1);
    let slo_ms = std::env::var("DAPD_CHAOS_SLO_MS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(20_000.0);

    let mut local: Vec<LocalServer> = Vec::new();
    let (chaos_addr, ref_addr) = match (args.get("addr"), args.get("ref-addr")) {
        (Some(a), Some(r)) => (a.to_string(), r.to_string()),
        (None, None) => {
            let plan = FaultPlan::parse(CHAOS_SPEC)?;
            let (chaos_addr, chaos_srv) = boot_local(Some(plan))?;
            let (ref_addr, ref_srv) = boot_local(None)?;
            println!("self-booted chaos server on {chaos_addr} (plan {CHAOS_SPEC})");
            println!("self-booted reference server on {ref_addr}");
            local.push(chaos_srv);
            local.push(ref_srv);
            (chaos_addr, ref_addr)
        }
        _ => bail!("--addr and --ref-addr must be given together (or neither)"),
    };

    // ---- phase 1: clean reference generations --------------------------
    let ps = prompts(4);
    let want = fetch_reference(&ref_addr, &ps)?;
    println!(
        "phase 1 reference: {} prompts x {} tokens fetched fault-free",
        ps.len(),
        want[0].len()
    );

    // ---- phase 2: the chaos wave ---------------------------------------
    let t0 = Instant::now();
    let mut latency = Summary::new();
    let mut accepted = 0usize;
    let mut failures: Vec<String> = Vec::new();
    for wave in 0..total.div_ceil(concurrency) {
        let handles: Vec<_> = (0..concurrency)
            .map(|j| wave * concurrency + j)
            .filter(|&i| i < total)
            .map(|i| {
                let addr = chaos_addr.clone();
                let prompt = ps[i % ps.len()].clone();
                let want = want[i % ps.len()].clone();
                std::thread::spawn(move || one_request(&addr, &prompt, &want))
            })
            .collect();
        for h in handles {
            match h.join().unwrap() {
                Outcome::Accepted { latency_ms } => {
                    accepted += 1;
                    latency.add(latency_ms);
                }
                Outcome::Diverged(e) => failures.push(format!("diverged: {e}")),
                Outcome::Lost(e) => failures.push(format!("lost: {e}")),
            }
        }
    }
    println!(
        "phase 2 chaos: {total} fired ({concurrency}-wide waves) -> {accepted} accepted \
         identical, {} failed, in {:.1}s",
        failures.len(),
        t0.elapsed().as_secs_f64()
    );
    if !failures.is_empty() {
        bail!(
            "phase 2: {} of {total} requests lost or divergent under faults, e.g. {}",
            failures.len(),
            failures[0]
        );
    }

    // ---- phase 3: accepted latency stays bounded -----------------------
    println!(
        "phase 3 SLO: accepted p50={:.1}ms p95={:.1}ms p99={:.1}ms (SLO {slo_ms:.0}ms)",
        latency.p50(),
        latency.p95(),
        latency.p99()
    );
    if latency.p99() > slo_ms {
        bail!(
            "phase 3: accepted-request p99 {:.1}ms exceeds the {slo_ms:.0}ms SLO \
             (recovery should bound tail latency, not just correctness)",
            latency.p99()
        );
    }

    // ---- phase 4: recovery counters in the exposition ------------------
    check_needles(&chaos_addr, total)?;

    // ---- phase 5: both servers drain cleanly ---------------------------
    drain(&chaos_addr)?;
    drain(&ref_addr)?;
    for srv in local {
        srv.server.join().unwrap();
        srv.pool.join();
        assert_eq!(srv.coord.inflight(), 0, "drained server left requests in flight");
    }
    println!("phase 5 drain: both servers acknowledged the in-band drain");

    if let Ok(path) = std::env::var("DAPD_CHAOS_JSON") {
        let mut lat = Json::obj();
        lat.set("p50", latency.p50().into());
        lat.set("p95", latency.p95().into());
        lat.set("p99", latency.p99().into());
        lat.set("max", latency.max().into());
        let mut out = Json::obj();
        out.set("bench", "chaos_smoke".into());
        out.set("spec", CHAOS_SPEC.into());
        out.set("total", total.into());
        out.set("accepted", accepted.into());
        out.set("lost", 0i64.into());
        out.set("slo_ms", slo_ms.into());
        out.set("latency_ms", lat);
        std::fs::write(&path, out.dump_pretty())?;
        println!("wrote chaos summary to {path}");
    }
    println!("chaos smoke passed: zero lost, zero divergent, tails bounded, counters exposed");
    Ok(())
}
