//! Serve smoke: boots (or attaches to) a serving front end and proves the
//! three admission-control stories end to end over real TCP:
//!
//!   1. token identity — a streamed request replays to exactly the batch
//!      response, frame by frame;
//!   2. overload — a bursty workload far above capacity is shed with fast
//!      typed refusals while every *accepted* request completes within
//!      the latency SLO;
//!   3. observability — `{"prometheus": true}` returns a well-formed
//!      exposition covering the served traffic, and `{"trace": true}`
//!      drains a Chrome trace with request spans, every decode stage,
//!      and per-step commit-width counters (written to the path in
//!      `DAPD_SMOKE_TRACE` for artifact upload);
//!   4. graceful drain — `{"drain": true}` refuses new work and loses
//!      zero accepted requests.
//!
//!     cargo run --release --example serve_smoke            # self-boot
//!     cargo run --release --example serve_smoke -- --addr HOST:PORT
//!
//! With `--addr`, drives an externally booted `dapd serve --mock` (the CI
//! serve-smoke job does this, with tight `--queue-cap`/`--max-inflight`
//! caps so the burst must shed).  Knobs:
//!
//!   --total N / --burst N / --period-ms X   overload shape (64 / 32 / 50)
//!   DAPD_SMOKE_SLO_MS    p99 SLO for accepted requests (default 5000)
//!   DAPD_SMOKE_JSON=f    write the latency/shed summary to `f`
//!   DAPD_SMOKE_TRACE=f   write the drained Chrome trace JSON to `f`
//!
//! The self-booted server runs with tracing and the cache on (so the
//! graph stage appears in the trace); an external server needs
//! `--trace --cache` for the trace phase to assert (without `--trace` it
//! is reported as skipped).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use dapd::cache::CacheConfig;
use dapd::coordinator::{Coordinator, CoordinatorHandle, PoolOptions};
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::{MockModel, ModelPool};
use dapd::server::{Client, Server};
use dapd::util::args::Args;
use dapd::util::json::Json;
use dapd::util::rng::Pcg;
use dapd::util::stats::Summary;
use dapd::workload::arrivals::Arrival;

const PROMPT_LEN: usize = 28;

enum Outcome {
    /// served in full
    Accepted { latency_ms: f64, gen_len: usize },
    /// fast admission-control shed (the 429 analogue)
    Shed,
    /// typed refusal that is not an overload (draining/expired)
    Refused,
    /// anything else — a lost request, a dropped connection, a malformed
    /// reply; zero of these are tolerated in any phase
    Lost(String),
}

fn prompt() -> Vec<i32> {
    vec![7i32; PROMPT_LEN]
}

fn one_request(addr: &str) -> Outcome {
    let t0 = Instant::now();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(e) => return Outcome::Lost(format!("connect: {e:#}")),
    };
    let mut req = Json::obj();
    req.set(
        "prompt",
        prompt().iter().map(|&t| t as i64).collect::<Vec<i64>>().into(),
    );
    let resp = match client.roundtrip(&req) {
        Ok(r) => r,
        Err(e) => return Outcome::Lost(format!("roundtrip: {e:#}")),
    };
    if resp.get("ok").as_bool() == Some(true) {
        let gen_len = resp.get("gen").to_i64_vec().map(|v| v.len()).unwrap_or(0);
        if gen_len == 0 {
            return Outcome::Lost(format!("ok reply without tokens: {}", resp.dump()));
        }
        return Outcome::Accepted {
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            gen_len,
        };
    }
    if resp.get("overloaded").as_bool() == Some(true) {
        Outcome::Shed
    } else if resp.get("draining").as_bool() == Some(true)
        || resp.get("expired").as_bool() == Some(true)
    {
        Outcome::Refused
    } else {
        Outcome::Lost(format!("untyped refusal: {}", resp.dump()))
    }
}

/// Phase 1: streamed tokens must replay to exactly the batch response.
fn check_identity(addr: &str) -> Result<()> {
    let mut client = Client::connect(addr)?;
    let mut req = Json::obj();
    req.set(
        "prompt",
        prompt().iter().map(|&t| t as i64).collect::<Vec<i64>>().into(),
    );
    let batch = client.roundtrip(&req)?;
    if batch.get("ok").as_bool() != Some(true) {
        bail!("identity: batch request refused: {}", batch.dump());
    }
    let want = batch.get("gen").to_i64_vec().unwrap_or_default();
    if want.is_empty() {
        bail!("identity: batch request returned no tokens");
    }

    req.set("stream", true.into());
    client.send(&req)?;
    let mut rebuilt: Vec<Option<i64>> = vec![None; want.len()];
    let done = loop {
        let frame = client.read_frame()?;
        if frame.get("ok").as_bool() != Some(true) {
            bail!("identity: stream refused mid-way: {}", frame.dump());
        }
        match frame.get("frame").as_str() {
            Some("tokens") => {
                let pos = frame.get("positions").to_i64_vec().unwrap_or_default();
                let tok = frame.get("tokens").to_i64_vec().unwrap_or_default();
                for (p, t) in pos.iter().zip(&tok) {
                    rebuilt[*p as usize] = Some(*t);
                }
            }
            Some("done") => break frame,
            other => bail!("identity: unexpected frame {other:?}"),
        }
    };
    let streamed: Vec<i64> = rebuilt
        .into_iter()
        .enumerate()
        .map(|(i, t)| t.ok_or_else(|| anyhow::anyhow!("position {i} never streamed")))
        .collect::<Result<_>>()?;
    if streamed != want {
        bail!("identity: streamed tokens != batch response\n  streamed {streamed:?}\n  batch    {want:?}");
    }
    if done.get("gen").to_i64_vec().unwrap_or_default() != want {
        bail!("identity: done frame disagrees with batch response");
    }
    println!("phase 1 identity: streamed == batch over {} tokens", want.len());
    Ok(())
}

/// Phase 3: the observability endpoints over the traffic phases 1-2
/// generated.  Prometheus must expose the served requests; the trace
/// drain must parse as Chrome trace JSON carrying request spans, every
/// decode stage, and per-step commit-width counters.
fn check_observability(addr: &str) -> Result<()> {
    let mut client = Client::connect(addr)?;

    let mut preq = Json::obj();
    preq.set("prometheus", true.into());
    let p = client.roundtrip(&preq)?;
    if p.get("ok").as_bool() != Some(true) {
        bail!("observability: prometheus request refused: {}", p.dump());
    }
    let text = p
        .get("text")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("prometheus reply without text"))?;
    for needle in [
        "# TYPE dapd_requests counter",
        "dapd_requests{worker=\"all\"}",
        "# TYPE dapd_stage_duration_seconds histogram",
        "dapd_inflight",
        // scheduler counters + the per-group queue-depth gauge series
        "dapd_steals{worker=\"all\"}",
        "dapd_preemptions{worker=\"all\"}",
        "dapd_queue_depth{group=\"",
    ] {
        if !text.contains(needle) {
            bail!("observability: exposition missing `{needle}`");
        }
    }
    println!(
        "phase 3 observability: prometheus exposition ok ({} lines)",
        text.lines().count()
    );

    let mut treq = Json::obj();
    treq.set("trace", true.into());
    let t = client.roundtrip(&treq)?;
    if t.get("ok").as_bool() != Some(true) {
        bail!("observability: trace request refused: {}", t.dump());
    }
    if t.get("enabled").as_bool() != Some(true) {
        println!(
            "phase 3 observability: tracing disabled on the server \
             (boot with --trace); trace assertions skipped"
        );
        return Ok(());
    }
    let chrome = t.get("trace");
    // must survive a JSON round-trip (what chrome://tracing would load)
    let rt = Json::parse(&chrome.dump())
        .map_err(|e| anyhow::anyhow!("trace is not valid JSON: {e}"))?;
    let evs = rt
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace without traceEvents"))?;
    let count = |name: &str| {
        evs.iter()
            .filter(|e| e.get("name").as_str() == Some(name))
            .count()
    };
    for name in [
        "request",
        "queue_wait",
        "forward",
        "feature",
        "graph",
        "select",
        "commit",
        "decode_step",
    ] {
        if count(name) == 0 {
            bail!("observability: trace has no `{name}` events");
        }
    }
    let committed = evs.iter().any(|e| {
        e.get("name").as_str() == Some("decode_step")
            && e.get("args").get("committed").as_i64().unwrap_or(0) >= 1
    });
    if !committed {
        bail!("observability: no decode_step event carries a commit width");
    }
    println!(
        "phase 3 observability: trace ok ({} events; {} request spans, \
         {} decode steps)",
        evs.len(),
        count("request"),
        count("decode_step")
    );
    if let Ok(path) = std::env::var("DAPD_SMOKE_TRACE") {
        std::fs::write(&path, rt.dump_pretty())?;
        println!("wrote Chrome trace to {path}");
    }
    Ok(())
}

/// Fire `n` requests on the given arrival schedule, one thread each.
fn drive(addr: &str, times: &[f64]) -> Vec<Outcome> {
    let t0 = Instant::now();
    let handles: Vec<_> = times
        .iter()
        .map(|&at| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let elapsed = t0.elapsed().as_secs_f64();
                if at > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(at - elapsed));
                }
                one_request(&addr)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let total = args.usize_or("total", 64);
    let burst = args.usize_or("burst", 32);
    let period = args.f64_or("period-ms", 50.0) / 1e3;
    let slo_ms = std::env::var("DAPD_SMOKE_SLO_MS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(5000.0);

    // self-boot a mock pool with tight caps unless attached to an
    // external server (CI boots `dapd serve --mock` and passes --addr)
    let mut local: Option<(std::thread::JoinHandle<()>, CoordinatorHandle)> = None;
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => {
            let pool = ModelPool::mock(MockModel::new(4, 68, PROMPT_LEN, 92));
            let opts = PoolOptions {
                workers: 2,
                batch_wait: Duration::from_millis(2),
                queue_cap: 4,
                max_inflight: 4,
                cache: CacheConfig {
                    enabled: true,
                    ..CacheConfig::default()
                },
                trace: true,
                ..PoolOptions::default()
            };
            let (coord, handles) = Coordinator::start_pool(&pool, &opts)?;
            let server = Server::bind(
                "127.0.0.1:0",
                coord,
                DecodeConfig::new(Method::DapdStaged),
            )?;
            let addr = server.local_addr()?.to_string();
            let sh = std::thread::spawn(move || server.run().unwrap());
            println!("self-booted mock server on {addr} (queue_cap=4, max_inflight=4)");
            local = Some((sh, handles));
            addr
        }
    };

    // ---- phase 1: token identity ---------------------------------------
    check_identity(&addr)?;

    // ---- phase 2: bursty overload gets shed, accepted stay in SLO ------
    let mut rng = Pcg::new(17);
    let times = Arrival::Bursty { burst, period }.schedule(total, &mut rng);
    let outcomes = drive(&addr, &times);
    let mut latency = Summary::new();
    let (mut accepted, mut shed, mut refused) = (0usize, 0usize, 0usize);
    let mut lost: Vec<String> = Vec::new();
    for o in &outcomes {
        match o {
            Outcome::Accepted { latency_ms, .. } => {
                accepted += 1;
                latency.add(*latency_ms);
            }
            Outcome::Shed => shed += 1,
            Outcome::Refused => refused += 1,
            Outcome::Lost(e) => lost.push(e.clone()),
        }
    }
    println!(
        "phase 2 overload: {total} fired (bursts of {burst}) -> {accepted} accepted, \
         {shed} shed, {refused} refused; accepted p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        latency.p50(),
        latency.p95(),
        latency.p99()
    );
    if !lost.is_empty() {
        bail!(
            "phase 2: {} request(s) lost without a typed reply, e.g. {}",
            lost.len(),
            lost[0]
        );
    }
    if accepted == 0 {
        bail!("phase 2: overload shed everything — the server served no work at all");
    }
    if shed == 0 {
        bail!(
            "phase 2: a {burst}-wide burst against tight caps shed nothing — \
             admission control is not engaging"
        );
    }
    if latency.p99() > slo_ms {
        bail!(
            "phase 2: accepted-request p99 {:.1}ms exceeds the {slo_ms:.0}ms SLO \
             (admission control should keep accepted latency bounded)",
            latency.p99()
        );
    }

    // ---- phase 3: observability endpoints ------------------------------
    check_observability(&addr)?;

    // ---- phase 4: graceful drain loses nothing -------------------------
    let drain_wave: Vec<f64> = vec![0.0; 8];
    let t0 = Instant::now();
    let workers: Vec<_> = drain_wave
        .iter()
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || one_request(&addr))
        })
        .collect();
    // let the wave land, then drain while it is (at most) mid-flight
    std::thread::sleep(Duration::from_millis(10));
    let mut admin = Client::connect(&addr)?;
    let mut dreq = Json::obj();
    dreq.set("drain", true.into());
    let ack = admin.roundtrip(&dreq)?;
    if ack.get("draining").as_bool() != Some(true) {
        bail!("drain request not acknowledged: {}", ack.dump());
    }
    let (mut drain_ok, mut drain_refused) = (0usize, 0usize);
    let mut drain_lost: Vec<String> = Vec::new();
    for h in workers {
        match h.join().unwrap() {
            Outcome::Accepted { .. } => drain_ok += 1,
            Outcome::Shed | Outcome::Refused => drain_refused += 1,
            Outcome::Lost(e) => drain_lost.push(e),
        }
    }
    println!(
        "phase 4 drain: {drain_ok} completed, {drain_refused} refused-typed, \
         {} lost (drain took {:.0}ms)",
        drain_lost.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    if !drain_lost.is_empty() {
        bail!(
            "phase 4: drain lost {} accepted/at-flight request(s), e.g. {}",
            drain_lost.len(),
            drain_lost[0]
        );
    }
    // post-drain, no new work may be accepted (refusal, closed connection,
    // or — once the process exits — connection refused are all fine)
    match one_request(&addr) {
        Outcome::Accepted { .. } => bail!("phase 4: server accepted work after drain"),
        _ => println!("phase 4: post-drain request correctly not served"),
    }

    if let Some((sh, handles)) = local {
        sh.join().unwrap();
        handles.join();
    }

    if let Ok(path) = std::env::var("DAPD_SMOKE_JSON") {
        let mut lat = Json::obj();
        lat.set("p50", latency.p50().into());
        lat.set("p95", latency.p95().into());
        lat.set("p99", latency.p99().into());
        lat.set("max", latency.max().into());
        let mut out = Json::obj();
        out.set("bench", "serve_smoke".into());
        out.set("total", total.into());
        out.set("accepted", accepted.into());
        out.set("shed", shed.into());
        out.set("refused", refused.into());
        out.set("slo_ms", slo_ms.into());
        out.set("latency_ms", lat);
        out.set("drain_completed", drain_ok.into());
        out.set("drain_lost", 0i64.into());
        std::fs::write(&path, out.dump_pretty())?;
        println!("wrote smoke summary to {path}");
    }
    println!(
        "serve smoke passed: identity + overload shedding + observability + \
         zero-loss drain"
    );
    Ok(())
}
