//! End-to-end serving demo: coordinator + TCP server + concurrent
//! clients, with latency/throughput metrics (the deployment the README
//! architecture diagram describes).
//!
//!     cargo run --release --example serve_demo [-- --requests 24]

use std::sync::atomic::Ordering;
use std::time::Duration;

use anyhow::Result;
use dapd::coordinator::Coordinator;
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::Engine;
use dapd::server::{Client, Server};
use dapd::util::args::Args;
use dapd::workload::{scorer, EvalSet};

fn main() -> Result<()> {
    let args = Args::parse_env();
    let n_requests = args.usize_or("requests", 24);
    let engine: &'static Engine = Box::leak(Box::new(Engine::load(
        std::path::Path::new(&args.str_or("artifacts", "artifacts")),
    )?));
    let model = engine.model_for("sim-llada", 4, engine.meta.gen_len)?;

    let (coord, _worker) = Coordinator::start(model, Duration::from_millis(5), 256);
    let server = Server::bind(
        "127.0.0.1:0",
        coord.clone(),
        DecodeConfig::new(Method::DapdStaged),
    )?;
    let addr = server.local_addr()?.to_string();
    let drain = server.drain_handle()?;
    let server_thread = std::thread::spawn(move || server.run());
    println!("serving on {addr}");

    // Mixed workload from three task families, over four client threads.
    let tasks = ["struct", "multiq", "arith"];
    let mut handles = Vec::new();
    for c in 0..4 {
        let addr = addr.clone();
        let task = tasks[c % tasks.len()].to_string();
        let meta = engine.meta.clone();
        let per_client = n_requests / 4;
        handles.push(std::thread::spawn(move || -> Result<(usize, f64)> {
            let set = EvalSet::load(&meta, &task)?.take(per_client);
            let mut client = Client::connect(&addr)?;
            let mut correct = 0.0;
            for inst in &set.instances {
                let resp = client.request(&inst.prompt, None)?;
                let gen: Vec<i32> = resp
                    .get("gen")
                    .to_i64_vec()
                    .unwrap_or_default()
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                correct += scorer::score(&task, &gen, &inst.expect, &inst.spec);
            }
            Ok((set.len(), correct))
        }));
    }
    let mut total = 0;
    let mut correct = 0.0;
    for h in handles {
        let (n, c) = h.join().unwrap()?;
        total += n;
        correct += c;
    }

    println!("\n{}", coord.metrics.report());
    println!(
        "served {total} requests, mixed-task accuracy {:.1}%, \
         mean batch size {:.2} (dynamic batching across clients)",
        100.0 * correct / total as f64,
        coord.metrics.mean_batch_size()
    );
    // ordering: Relaxed — advisory sanity read after all clients joined.
    assert!(coord.metrics.requests.load(Ordering::Relaxed) as usize >= total);

    drain.drain();
    server_thread.join().unwrap()?;
    Ok(())
}
