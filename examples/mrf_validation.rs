//! Sec. 3.2 MRF validation walk-through: show the toy dataset's ground
//! truth, then check how well the trained toy models' attention recovers
//! it (the quick version of `cargo bench --bench table1_mrf`).
//!
//!     cargo run --release --example mrf_validation [-- --paths 30]

use anyhow::Result;
use dapd::eval::mrf::{run_mrf_validation, LayerSel};
use dapd::runtime::{ArtifactKind, Engine};
use dapd::util::args::Args;
use dapd::util::bench::{fmt_f, Table};

fn main() -> Result<()> {
    let args = Args::parse_env();
    let paths = args.usize_or("paths", 30);
    let engine = Engine::load(std::path::Path::new(&args.str_or("artifacts", "artifacts")))?;
    let spec = &engine.meta.mrf;

    println!("ground-truth MRF (X1..X5 uniform, Y_i = (X_i + X_{{i+1}}) mod 3):");
    println!("  edges:   {:?}", spec.true_edges);
    println!("  degrees: {:?}  (X2..X4 are the high-degree hubs)", spec.true_degrees);

    let toys: Vec<String> = engine
        .meta
        .artifacts
        .iter()
        .filter(|a| a.kind == ArtifactKind::Toy && a.batch > 1)
        .map(|a| a.name.clone())
        .collect();

    let mut t = Table::new(
        &format!("Attention vs ground truth ({paths} random paths)"),
        &["Model", "Layers", "AUC", "Edge/Non-edge", "OVR"],
    );
    for name in &toys {
        let info = engine.meta.find_by_name(name)?.clone();
        let model = engine.model(name)?;
        for sel in [LayerSel::LastK(2), LayerSel::All] {
            let s = run_mrf_validation(&model, spec, info.n_layers, sel, paths, 7)?;
            t.row(vec![
                name.clone(),
                sel.label(),
                fmt_f(s.auc, 3),
                fmt_f(s.ratio, 2),
                fmt_f(s.ovr, 3),
            ]);
        }
    }
    t.print();
    println!("paper (Table 1, last-2): AUC 0.928, ratio 2.204, OVR 0.04");
    Ok(())
}
