//! Sec. 6 decoding-behavior analysis on the bundled-questions workload
//! (the paper's Fig. 1 / Fig. 5 / Table 2).
//!
//!     cargo run --release --example multiq_analysis [-- --n 60]
//!
//! Prints, per method: accuracy, steps, speedup vs Original (Table 2),
//! the mean-segment-count curve (Fig. 5 right), and an ASCII unmasking
//! trajectory heatmap for the first sample (Fig. 1): earlier-unmasked
//! positions get darker glyphs.  Also dumps trajectories as JSON for
//! external plotting.

use anyhow::Result;
use dapd::decode::{DecodeConfig, Method};
use dapd::eval::{run_eval, segments, trajectory_json};
use dapd::runtime::{Engine, ForwardModel};
use dapd::util::args::Args;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn heat_glyph(frac: f64) -> char {
    // earlier commit = darker
    const RAMP: [char; 5] = ['#', '*', '+', '.', ' '];
    let idx = (frac * (RAMP.len() - 1) as f64).round() as usize;
    RAMP[idx.min(RAMP.len() - 1)]
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let n = args.usize_or("n", 60);
    let engine = Engine::load(std::path::Path::new(&args.str_or("artifacts", "artifacts")))?;
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len)?;
    let set = EvalSet::load(&engine.meta, "multiq")?.take(n);
    let gen_len = model.gen_len();

    let mut table = Table::new(
        &format!("Table 2 analogue: multiq (n={n})"),
        &["Method", "Acc.", "Steps", "Speedup", "PeakSegs"],
    );
    let mut base_steps = 0.0;
    let methods = [
        Method::Original,
        Method::FastDllm,
        Method::Klass,
        Method::EbSampler,
        Method::DapdStaged,
    ];
    for method in methods {
        let cfg = DecodeConfig::new(method);
        let r = run_eval(&model, &set, &cfg, method.name())?;
        if method == Method::Original {
            base_steps = r.avg_steps;
        }
        table.row(vec![
            method.name().into(),
            fmt_f(r.accuracy_pct(), 2),
            fmt_f(r.avg_steps, 1),
            format!("{:.2}x", r.speedup_vs(base_steps).max(0.0)),
            fmt_f(segments::peak_segments(&r.outcomes, gen_len), 2),
        ]);

        // Fig. 5 right: mean segment-count curve over normalized progress
        let curve = segments::mean_segment_curve(&r.outcomes, gen_len, 10);
        println!(
            "segments[{}]: {}",
            method.name(),
            curve.iter().map(|c| format!("{c:.1}")).collect::<Vec<_>>().join(" ")
        );

        // Fig. 1: trajectory of sample 0 (normalized commit step -> glyph)
        let o = &r.outcomes[0];
        let total = o.steps.max(1) as f64;
        let row: String = o
            .commit_step
            .iter()
            .map(|&s| heat_glyph(s as f64 / total))
            .collect();
        println!("trajectory[{}]: |{row}|", method.name());

        // JSON dump for plotting
        let path = format!("artifacts/trajectories_{}.json", method.name());
        std::fs::write(&path, trajectory_json(&r.outcomes).dump())?;
    }
    table.print();
    println!("\n('#' = unmasked earliest, ' ' = last; DAPD should disperse");
    println!(" across the five answer segments while baselines stay contiguous)");
    Ok(())
}
