//! Open-loop load test: Poisson arrivals against the coordinator, with
//! latency percentiles and backpressure accounting — the serving-side
//! stress test behind the Table 6 TPS claims.
//!
//!     cargo run --release --example load_test [-- --rate 2.0 --requests 40]

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use anyhow::Result;
use dapd::coordinator::{Coordinator, Response};
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::Engine;
use dapd::util::args::Args;
use dapd::util::rng::Pcg;
use dapd::util::stats::Summary;
use dapd::workload::{arrivals::Arrival, EvalSet};

fn main() -> Result<()> {
    let args = Args::parse_env();
    let rate = args.f64_or("rate", 2.0); // requests/second
    let n = args.usize_or("requests", 40);
    let engine: &'static Engine = Box::leak(Box::new(Engine::load(
        std::path::Path::new(&args.str_or("artifacts", "artifacts")),
    )?));
    let model = engine.model_for("sim-llada", 4, engine.meta.gen_len)?;
    let (coord, _worker) = Coordinator::start(model, Duration::from_millis(4), 64);

    let set = EvalSet::load(&engine.meta, "struct")?;
    let mut rng = Pcg::new(11);
    let schedule = Arrival::Poisson { rate }.schedule(n, &mut rng);

    let t0 = Instant::now();
    let mut pending: Vec<Receiver<Response>> = Vec::new();
    let mut rejected = 0usize;
    for (i, &at) in schedule.iter().enumerate() {
        let now = t0.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        let inst = &set.instances[i % set.len()];
        match coord.submit(inst.prompt.clone(), DecodeConfig::new(Method::DapdStaged)) {
            Ok(rx) => pending.push(rx),
            Err(_) => rejected += 1, // backpressure: queue full
        }
    }
    let mut lat = Summary::new();
    let mut tokens = 0usize;
    for rx in pending {
        let r = rx.recv()?;
        lat.add(r.latency.as_secs_f64());
        tokens += r.gen.len();
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\nopen-loop @ {rate} req/s, {n} requests ({rejected} rejected by backpressure)");
    println!(
        "completed {} in {wall:.1}s -> {:.2} req/s, {:.1} tok/s",
        lat.count(),
        lat.count() as f64 / wall,
        tokens as f64 / wall
    );
    println!(
        "latency p50 {:.2}s  p95 {:.2}s  p99 {:.2}s  max {:.2}s",
        lat.p50(),
        lat.p95(),
        lat.p99(),
        lat.max()
    );
    println!("{}", coord.metrics.report());
    coord.shutdown();
    Ok(())
}
