//! Load test against the coordinator's sharded worker pool, with latency
//! percentiles, backpressure accounting, and a worker-scaling comparison
//! — the serving-side stress test behind the Table 6 TPS claims.
//!
//! By default it runs closed-loop (all requests submitted at once) on the
//! mock model for each worker count in `--workers`, checks that every
//! request's generation is token-for-token identical across pool sizes,
//! and prints the aggregate-throughput speedup:
//!
//!     cargo run --release --example load_test
//!     cargo run --release --example load_test -- --workers 1,4 --requests 64
//!     cargo run --release --example load_test -- --rate 2.0     # Poisson open loop
//!     cargo run --release --example load_test -- --artifacts artifacts  # PJRT
//!
//! When artifacts are present (and `--mock` is not given) the prompts come
//! from the exported `struct` eval set and the pool compiles per-worker
//! PJRT executables; otherwise it falls back to the synthetic model.

use std::collections::HashMap;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use dapd::coordinator::{Coordinator, PoolOptions, Response};
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::{Engine, MockModel, ModelPool};
use dapd::util::args::Args;
use dapd::util::bench::{fmt_f, Table};
use dapd::util::rng::Pcg;
use dapd::util::stats::Summary;
use dapd::workload::{arrivals::Arrival, EvalSet};

struct RunStats {
    wall: f64,
    tokens: usize,
    rejected: usize,
    lat: Summary,
    /// request index -> generation (for cross-pool identity checks)
    gens: HashMap<usize, Vec<i32>>,
}

fn run_load(
    pool: &ModelPool,
    workers: usize,
    prompts: &[Vec<i32>],
    schedule: &[f64],
    queue_cap: usize,
) -> Result<RunStats> {
    let opts = PoolOptions {
        workers,
        batch_wait: Duration::from_millis(4),
        queue_cap,
        ..PoolOptions::default()
    };
    let (coord, handles) = Coordinator::start_pool(pool, &opts)?;
    let t0 = Instant::now();
    let mut pending: Vec<(usize, Receiver<Response>)> = Vec::new();
    let mut rejected = 0usize;
    for (i, &at) in schedule.iter().enumerate() {
        let now = t0.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        let prompt = prompts[i % prompts.len()].clone();
        match coord.submit(prompt, DecodeConfig::new(Method::DapdStaged)) {
            Ok(rx) => pending.push((i, rx)),
            Err(_) => rejected += 1, // backpressure: queue full
        }
    }
    let mut lat = Summary::new();
    let mut tokens = 0usize;
    let mut gens = HashMap::new();
    for (i, rx) in pending {
        let r = rx.recv()??;
        lat.add(r.latency.as_secs_f64());
        tokens += r.gen.len();
        gens.insert(i, r.gen);
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    handles.join();
    Ok(RunStats {
        wall,
        tokens,
        rejected,
        lat,
        gens,
    })
}

fn mock_setup(n: usize) -> (ModelPool, Vec<Vec<i32>>) {
    // shapes mirror the sim-llada artifact family (batch 4, L=68, V=92)
    let model = MockModel::new(4, 68, 28, 92);
    let mut rng = Pcg::new(7);
    let prompts = (0..n)
        .map(|_| (0..28).map(|_| (2 + rng.below(90)) as i32).collect())
        .collect();
    (ModelPool::mock(model), prompts)
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let n = args.usize_or("requests", 48);
    let rate = args.f64_or("rate", 0.0); // req/s; 0 = closed loop
    let worker_counts: Vec<usize> = args
        .list_or("workers", &["1", "4"])
        .iter()
        .map(|w| w.parse().expect("--workers expects a list of integers"))
        .collect();
    if worker_counts.is_empty() {
        bail!("--workers needs at least one pool size");
    }

    let (pool, prompts) = if args.has("mock") {
        mock_setup(n)
    } else {
        let dir = args.str_or("artifacts", "artifacts");
        match Engine::load(std::path::Path::new(&dir)) {
            Ok(engine) => {
                let engine = Arc::new(engine);
                let set = EvalSet::load(&engine.meta, "struct")?;
                let prompts: Vec<Vec<i32>> = (0..n)
                    .map(|i| set.instances[i % set.len()].prompt.clone())
                    .collect();
                let gen_len = engine.meta.gen_len;
                (ModelPool::pjrt(engine, "sim-llada", 4, gen_len)?, prompts)
            }
            Err(e) => {
                eprintln!("artifacts unavailable ({e:#}); using the mock model");
                mock_setup(n)
            }
        }
    };

    run_all(pool, prompts, n, rate, &worker_counts)
}

fn run_all(
    pool: ModelPool,
    prompts: Vec<Vec<i32>>,
    n: usize,
    rate: f64,
    worker_counts: &[usize],
) -> Result<()> {
    let mut rng = Pcg::new(11);
    let schedule = if rate > 0.0 {
        Arrival::Poisson { rate }.schedule(n, &mut rng)
    } else {
        Arrival::Closed.schedule(n, &mut rng)
    };
    // closed-loop comparisons want zero rejects so generations line up
    let queue_cap = if rate > 0.0 { 64 } else { n + 8 };

    let mode = if rate > 0.0 {
        format!("open loop @ {rate} req/s")
    } else {
        "closed loop".to_string()
    };
    println!(
        "load test: {} on {}, {n} requests, pools {:?}",
        mode,
        pool.describe(),
        worker_counts
    );

    let mut t = Table::new(
        "Aggregate throughput vs worker count",
        &[
            "workers", "done", "rej", "wall (s)", "req/s", "tok/s", "p50 (s)", "p95 (s)",
            "speedup",
        ],
    );
    let mut baseline: Option<RunStats> = None;
    let mut compared = 0usize;
    for &w in worker_counts {
        let stats = run_load(&pool, w, &prompts, &schedule, queue_cap)?;
        let tput = stats.tokens as f64 / stats.wall;
        let speedup = match &baseline {
            Some(b) => tput / (b.tokens as f64 / b.wall),
            None => 1.0,
        };
        t.row(vec![
            w.to_string(),
            stats.lat.count().to_string(),
            stats.rejected.to_string(),
            fmt_f(stats.wall, 2),
            fmt_f(stats.lat.count() as f64 / stats.wall, 2),
            fmt_f(tput, 1),
            fmt_f(stats.lat.p50(), 3),
            fmt_f(stats.lat.p95(), 3),
            fmt_f(speedup, 2),
        ]);
        if let Some(b) = &baseline {
            // per-request generations must be identical to the
            // single-worker baseline: pooling must never change outputs
            for (i, gen) in &stats.gens {
                if let Some(base_gen) = b.gens.get(i) {
                    if gen != base_gen {
                        bail!(
                            "request {i}: {w}-worker pool diverged from the \
                             {}-worker baseline",
                            worker_counts[0]
                        );
                    }
                    compared += 1;
                }
            }
        } else {
            baseline = Some(stats);
        }
    }
    t.print();
    if compared > 0 {
        println!(
            "checked {compared} generations against the {}-worker baseline: identical",
            worker_counts[0]
        );
    } else if worker_counts.len() > 1 {
        println!("warning: no request completed in both runs — identity unverified");
    }
    Ok(())
}
