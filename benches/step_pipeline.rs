//! Step-pipeline bench: the zero-alloc arena + CSR feature path versus
//! the seed's dense per-step derivation, under a *counting global
//! allocator*.
//!
//! Two claims are asserted, not just reported:
//!
//!   * **zero steady-state allocations** — after a warmup that grows the
//!     arena and strategy scratch to peak size, a full pipeline step
//!     (feature derivation + strategy selection for every board slot)
//!     performs exactly 0 heap allocations, for every method;
//!   * **CSR beats dense** — steps/s of the arena pipeline vs the seed's
//!     dense derivation (fresh O(n*v) and O(n^2) buffers each step,
//!     dense gather + normalize + row-sum degrees) for the
//!     dependency-aware methods, gated at `DAPD_MIN_PIPELINE_SPEEDUP`
//!     (default 1.0);
//!   * **zero allocations across slot churn** — with the shared
//!     [`BufferPool`] attached, a warm board performs exactly 0 heap
//!     allocations across repeated admit/release cycles, extending the
//!     steady-state contract across request turnover, not just within
//!     one slot's lifetime.
//!
//! The model forward is outside the measured unit (its cost belongs to
//! the backend; the `cache_reuse` bench covers forward reuse) — one mock
//! forward output is derived repeatedly, which is exactly the steady
//! state the serving loop sees between commits.
//!
//! Environment knobs (CI's bench-smoke job uses them):
//!   DAPD_ITERS=N                 timed pipeline steps per mode (default 300)
//!   DAPD_BENCH_JSON=f            write a JSON summary to `f`
//!   DAPD_MIN_PIPELINE_SPEEDUP=x  CSR-vs-dense gate on the DAPD methods

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use dapd::alloc::BufferPool;
use dapd::decode::features::{derive_slot, ModelDims, StepArena};
use dapd::decode::{make_strategy, DecodeConfig, Method, MethodParams, SlotBatch, StepCtx, Strategy};
use dapd::graph::{max_normalize, DepGraph, EdgeScores};
use dapd::runtime::{ForwardModel, MockModel, StepOutput};
use dapd::tensor::{argmax, entropy, softmax_inplace};
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::json::Json;

/// Counts every allocation (alloc / alloc_zeroed / realloc) so the
/// steady-state zero-alloc claim is checkable, not aspirational.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` plus a Relaxed counter bump —
// every `GlobalAlloc` contract obligation is delegated unchanged.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards `layout` to `System` untouched.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — monotone tally, read only after joins.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: as `alloc`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — as `alloc`.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: as `alloc` — `ptr`/`layout` come from this allocator.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: Relaxed — as `alloc`.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: as `alloc` — `ptr`/`layout` come from this allocator.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    // ordering: Relaxed — tally read; the measured section runs on this
    // thread or is joined before the read.
    ALLOCS.load(Ordering::Relaxed)
}

/// One steady-state step of the arena + CSR pipeline over the whole
/// board: derive features into each slot's arena, select with the warm
/// strategy.  Allocation-free once warm.
#[allow(clippy::too_many_arguments)]
fn csr_step(
    cfg: &DecodeConfig,
    dims: &ModelDims,
    tokens: &[i32],
    out: &StepOutput,
    arenas: &mut [StepArena],
    strategy: &mut dyn Strategy,
    sel: &mut Vec<usize>,
) {
    let l = dims.seq_len;
    for (s, arena) in arenas.iter_mut().enumerate() {
        derive_slot(cfg, dims, &tokens[s * l..(s + 1) * l], out, s, 0, arena);
        let a = &*arena;
        let masked = a.meta.masked_total as f32;
        let ctx = StepCtx {
            positions: &a.positions,
            conf: &a.conf,
            argmax_tok: &a.amax,
            entropy: &a.entropy,
            kl_prev: &a.kl,
            edges: &a.edges,
            degrees: &a.degrees,
            progress: a.meta.progress,
            mask_ratio: masked / dims.gen_len as f32,
            graph: None,
        };
        strategy.select(&ctx, sel);
        if sel.is_empty() {
            sel.push(argmax(&a.conf).0);
        }
        sel.sort_unstable();
        sel.dedup();
        std::hint::black_box(sel.len());
    }
}

/// The seed's DAPD selection, replicated densely: a from-scratch
/// `DepGraph::from_scores` over the dense matrix, allocating
/// Welsh-Powell, the `selected.contains` staged shortcut — exactly the
/// per-step work the seed paid, with no CSR involved (keeping the
/// baseline fair: the seed never built a CSR).
#[allow(clippy::too_many_arguments)]
fn dense_dapd_select(
    params: &MethodParams,
    direct: bool,
    conf: &[f32],
    degrees: &[f32],
    scores: &[f32],
    n: usize,
    progress: f32,
    mask_ratio: f32,
) -> Vec<usize> {
    let tau = params.tau.at(progress);
    let mut pre_committed = Vec::new();
    let mut eligible = vec![true; n];
    if direct {
        for c in 0..n {
            if params.dapd_pre_commits(conf[c]) {
                pre_committed.push(c);
                eligible[c] = false;
            }
        }
    }
    let graph = DepGraph::from_scores(
        n,
        |i, j| {
            if eligible[i] && eligible[j] {
                scores[i * n + j]
            } else {
                f32::NEG_INFINITY
            }
        },
        tau,
    );
    let priority: Vec<f32> = (0..n)
        .map(|c| {
            if eligible[c] {
                degrees[c] * conf[c]
            } else {
                f32::NEG_INFINITY
            }
        })
        .collect();
    let mut selected: Vec<usize> = graph
        .welsh_powell_set(&priority)
        .into_iter()
        .filter(|&c| eligible[c])
        .collect();
    if !direct && mask_ratio < params.stage_ratio {
        for c in 0..n {
            if conf[c] > params.conf_threshold && !selected.contains(&c) {
                selected.push(c);
            }
        }
    }
    selected.extend(pre_committed);
    selected
}

/// The seed's dense derivation for the same board: fresh conf/entropy
/// buffers, a fresh O(n*v) probability buffer and a fresh dense O(n^2)
/// score matrix per slot per step, gathered, max-normalized and
/// row-summed.  DAPD selection runs the seed's dense graph build
/// (`dense_dapd_select`); the other methods never read edge scores, so
/// they go through the shared strategies over an empty CSR.
fn dense_step(
    cfg: &DecodeConfig,
    dims: &ModelDims,
    tokens: &[i32],
    out: &StepOutput,
    strategy: &mut dyn Strategy,
) {
    let l = dims.seq_len;
    let p = dims.prompt_len;
    let g = dims.gen_len;
    let v = dims.vocab;
    let is_dapd = matches!(cfg.method, Method::DapdStaged | Method::DapdDirect);
    for s in 0..out.batch {
        let row = &tokens[s * l..(s + 1) * l];
        let positions: Vec<usize> = (p..p + g).filter(|&i| row[i] == dims.mask_id).collect();
        let n = positions.len();
        let mut conf = vec![0.0f32; n];
        let mut amax = vec![0i32; n];
        let mut ent = vec![0.0f32; n];
        let kl = vec![f32::INFINITY; n];
        let mut probs_buf = vec![0.0f32; n * v];
        for (c, &pos) in positions.iter().enumerate() {
            let pb = &mut probs_buf[c * v..(c + 1) * v];
            pb.copy_from_slice(out.logits.slice3(s, pos));
            softmax_inplace(pb);
            let (ai, av) = argmax(pb);
            conf[c] = av;
            amax[c] = ai as i32;
            ent[c] = entropy(pb);
        }
        let masked = n as f32;
        let progress = 1.0 - masked / g as f32;
        let mask_ratio = masked / g as f32;
        let mut sel: Vec<usize>;
        if is_dapd {
            let mut scores = vec![0.0f32; n * n];
            let mut degrees = vec![0.0f32; n];
            let es = out.edge_scores.as_ref().unwrap();
            for (ci, &i) in positions.iter().enumerate() {
                for (cj, &j) in positions.iter().enumerate() {
                    if ci != cj {
                        scores[ci * n + cj] = es.at3(s, i, j);
                    }
                }
            }
            max_normalize(&mut scores);
            for ci in 0..n {
                degrees[ci] = scores[ci * n..(ci + 1) * n].iter().sum();
            }
            sel = dense_dapd_select(
                &cfg.params,
                cfg.method == Method::DapdDirect,
                &conf,
                &degrees,
                &scores,
                n,
                progress,
                mask_ratio,
            );
        } else {
            let mut edges = EdgeScores::new();
            edges.begin(n);
            for _ in 0..n {
                edges.end_row();
            }
            let degrees = vec![0.0f32; n];
            let ctx = StepCtx {
                positions: &positions,
                conf: &conf,
                argmax_tok: &amax,
                entropy: &ent,
                kl_prev: &kl,
                edges: &edges,
                degrees: &degrees,
                progress,
                mask_ratio,
                graph: None,
            };
            sel = Vec::new();
            strategy.select(&ctx, &mut sel);
        }
        if sel.is_empty() {
            sel.push(argmax(&conf).0);
        }
        sel.sort_unstable();
        sel.dedup();
        std::hint::black_box(sel.len());
    }
}

fn main() {
    let iters: usize = std::env::var("DAPD_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let warmup = (iters / 10).max(5);

    // serving shape: long prompt, 32-candidate window, sparse banded
    // attention — the regime where nnz << n^2
    let model = MockModel::new(4, 128, 96, 256);
    let dims = ModelDims::of(&model);
    let l = dims.seq_len;
    let mut tokens = vec![7i32; model.batch * l];
    for s in 0..model.batch {
        for i in dims.prompt_len..l {
            tokens[s * l + i] = dims.mask_id;
        }
    }
    let out = model.forward(&tokens).unwrap();

    let mut table = Table::new(
        "Step pipeline: dense (seed) vs arena+CSR (mock, b=4 L=128 P=96 V=256)",
        &["method", "mode", "us/step", "steps/s", "speedup", "allocs/step"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut min_dapd_speedup = f64::INFINITY;

    for method in Method::all() {
        let cfg = DecodeConfig::new(method);

        // ---- dense baseline (allocating, as the seed did) --------------
        let mut dense_strategy = make_strategy(method, cfg.params);
        let (t_dense, _) = time_it(
            || dense_step(&cfg, &dims, &tokens, &out, dense_strategy.as_mut()),
            warmup,
            iters,
        );
        let a0 = allocs();
        dense_step(&cfg, &dims, &tokens, &out, dense_strategy.as_mut());
        let dense_allocs = allocs() - a0;

        // ---- arena + CSR pipeline --------------------------------------
        let mut arenas: Vec<StepArena> = (0..model.batch).map(|_| StepArena::new()).collect();
        for a in &mut arenas {
            a.reset_request(dims.gen_len, dims.vocab);
        }
        let mut strategy = make_strategy(method, cfg.params);
        let mut sel: Vec<usize> = Vec::new();
        // warm the arenas and every strategy scratch buffer
        for _ in 0..warmup {
            csr_step(
                &cfg,
                &dims,
                &tokens,
                &out,
                &mut arenas,
                strategy.as_mut(),
                &mut sel,
            );
        }
        // ---- the zero-alloc assertion ----------------------------------
        let check_steps = 50usize;
        let a0 = allocs();
        for _ in 0..check_steps {
            csr_step(
                &cfg,
                &dims,
                &tokens,
                &out,
                &mut arenas,
                strategy.as_mut(),
                &mut sel,
            );
        }
        let steady_allocs = allocs() - a0;
        assert_eq!(
            steady_allocs, 0,
            "{method:?}: {steady_allocs} allocations across {check_steps} \
             steady-state pipeline steps (must be 0)"
        );
        let (t_csr, _) = time_it(
            || {
                csr_step(
                    &cfg,
                    &dims,
                    &tokens,
                    &out,
                    &mut arenas,
                    strategy.as_mut(),
                    &mut sel,
                )
            },
            warmup,
            iters,
        );

        let speedup = t_dense / t_csr;
        if matches!(method, Method::DapdStaged | Method::DapdDirect) {
            min_dapd_speedup = min_dapd_speedup.min(speedup);
        }
        for (mode, t, n_allocs) in [
            ("dense", t_dense, dense_allocs as i64),
            ("csr", t_csr, 0i64),
        ] {
            table.row(vec![
                method.name().to_string(),
                mode.to_string(),
                fmt_f(t * 1e6, 1),
                fmt_f(1.0 / t, 0),
                fmt_f(if mode == "csr" { speedup } else { 1.0 }, 2),
                n_allocs.to_string(),
            ]);
            let mut r = Json::obj();
            r.set("method", method.name().into());
            r.set("mode", mode.into());
            r.set("mean_us", (t * 1e6).into());
            r.set("steps_per_s", (1.0 / t).into());
            r.set("allocs_per_step", n_allocs.into());
            rows.push(r);
        }
    }
    table.print();

    // ---- slot-churn section: the pooled allocator extends the
    // zero-alloc contract across admit/retire, not just within a slot's
    // lifetime (the per-step sections above) ---------------------------
    let churn_model = MockModel::new(4, 64, 24, 48);
    let churn_cfg = DecodeConfig::new(Method::DapdStaged);
    let churn_prompt = vec![7i32; 24];
    let pool = Arc::new(BufferPool::new(16));
    let mut board = SlotBatch::new(&churn_model, &churn_cfg).unwrap();
    board.attach_pool(Arc::clone(&pool));
    // warm: grow the arenas, strategies, and pool free lists to peak
    for _ in 0..5 {
        for id in 0..4u64 {
            board.admit(id, &churn_prompt).unwrap();
        }
        for id in 0..4u64 {
            assert!(board.release(id), "admitted slot must release");
        }
    }
    let churn_cycles = 50usize;
    let a0 = allocs();
    for _ in 0..churn_cycles {
        for id in 0..4u64 {
            board.admit(id, &churn_prompt).unwrap();
        }
        for id in 0..4u64 {
            board.release(id);
        }
    }
    let churn_allocs = allocs() - a0;
    let ps = pool.stats();
    println!(
        "\nslot churn: {churn_allocs} allocations across {churn_cycles} warm \
         admit/release cycles (pool: {} acquires, {} hits, {} misses, {} pooled)",
        ps.acquires,
        ps.hits,
        ps.misses,
        pool.pooled()
    );
    assert_eq!(
        churn_allocs, 0,
        "{churn_allocs} allocations across {churn_cycles} warm admit/release \
         cycles (the pooled allocator must make slot churn allocation-free)"
    );
    assert!(
        ps.hits > 0 && ps.dropped == 0,
        "churn must reuse pooled buffers (stats: {ps:?})"
    );

    let min_required: f64 = std::env::var("DAPD_MIN_PIPELINE_SPEEDUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    println!(
        "\nzero steady-state allocations: PASS (all methods); minimum DAPD \
         CSR-vs-dense speedup: {min_dapd_speedup:.2}x (gate: {min_required:.2}x)"
    );
    assert!(
        min_dapd_speedup >= min_required,
        "CSR pipeline must reach >= {min_required:.2}x the dense path on the \
         DAPD methods (got {min_dapd_speedup:.2}x)"
    );

    if let Ok(path) = std::env::var("DAPD_BENCH_JSON") {
        let mut summary = Json::obj();
        summary.set("bench", "step_pipeline".into());
        summary.set("zero_alloc_steady_state", true.into());
        summary.set("zero_alloc_slot_churn", true.into());
        summary.set("min_dapd_speedup", min_dapd_speedup.into());
        summary.set("rows", Json::Arr(rows));
        match std::fs::write(&path, summary.dump()) {
            Ok(()) => println!("wrote JSON summary to {path}"),
            Err(e) => eprintln!("failed writing {path}: {e}"),
        }
    }
}
