//! Ablation: DAPD's Welsh-Powell priority rule (Sec. 4.3 design choice).
//!
//! The paper motivates ordering by confidence-weighted proxy degree
//! (d~_i * conf_i): hubs resolve first (sparsifying the residual graph)
//! but only when they are reliably predictable.  This bench compares it
//! against raw degree, confidence-only, and positional ordering on the
//! multiq workload (steps at matched accuracy).

mod common;

use dapd::decode::{DapdOrdering, Method};
use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len).unwrap();

    let rules = [
        (DapdOrdering::ConfDegree, "conf*degree (paper)"),
        (DapdOrdering::Degree, "degree"),
        (DapdOrdering::Conf, "confidence"),
        (DapdOrdering::Index, "position"),
    ];
    let mut t = Table::new(
        &format!("Ablation: DAPD ordering rule (multiq + struct, n={n})"),
        &["Task", "Ordering", "Acc.", "Steps"],
    );
    for task in ["multiq", "struct"] {
        let set = EvalSet::load(&engine.meta, task).unwrap().take(n);
        for (rule, label) in rules {
            let mut cfg = common::cfg(Method::DapdStaged);
            cfg.params.ordering = rule;
            let r = run_eval(&model, &set, &cfg, label).unwrap();
            t.row(vec![
                task.into(),
                label.into(),
                fmt_f(r.accuracy_pct(), 1),
                fmt_f(r.avg_steps, 1),
            ]);
        }
    }
    t.print();
    println!("expected: conf*degree dominates — degree-only risks committing");
    println!("unreliable hubs, confidence-only ignores residual-graph shape");
}
