//! Fig. 6: distribution of normalized mask-to-mask edge scores, and where
//! the tau_min choices sit in its tail.
//!
//! Protocol mirrors App. A: decode step-by-step (Original) on the Sec. 6
//! multiq workload, collecting the max-normalized pairwise edge scores
//! among still-masked positions at every step, for both models.  Paper
//! shape: the mass concentrates near zero; tau_min in {0.005, 0.01}
//! admits almost all pairs early (the CDF below tau_min is tiny).

mod common;

 
use dapd::graph::max_normalize;
use dapd::runtime::ForwardModel;
use dapd::util::bench::{fmt_f, Table};
use dapd::util::stats::Histogram;
use dapd::workload::EvalSet;

fn collect_hist(engine: &dapd::runtime::Engine, model_name: &str, n: usize) -> Histogram {
    let model = engine.model_for(model_name, 8, engine.meta.gen_len).unwrap();
    let set = EvalSet::load(&engine.meta, "multiq").unwrap().take(n);
    let mut hist = Histogram::new(0.0, 1.0, 100);
    let p = model.prompt_len();
    let l = model.seq_len();
    let mask_id = model.mask_id();

    // step-by-step decode, harvesting edge scores at every forward
    for chunk in set.instances.chunks(model.batch()) {
        let mut tokens = vec![0i32; model.batch() * l];
        for (s, inst) in chunk.iter().enumerate() {
            tokens[s * l..s * l + p].copy_from_slice(&inst.prompt);
            for i in p..l {
                tokens[s * l + i] = mask_id;
            }
        }
        for s in chunk.len()..model.batch() {
            let (head, tail) = tokens.split_at_mut(s * l);
            tail[..l].copy_from_slice(&head[..l]);
        }
        for _step in 0..model.gen_len() {
            let out = model.forward(&tokens).unwrap();
            let es = out.edge_scores.as_ref().unwrap();
            for (s, _inst) in chunk.iter().enumerate() {
                let masked: Vec<usize> =
                    (p..l).filter(|&i| tokens[s * l + i] == mask_id).collect();
                if masked.len() < 2 {
                    continue;
                }
                let mut scores = Vec::with_capacity(masked.len() * masked.len());
                for &i in &masked {
                    for &j in &masked {
                        if i != j {
                            scores.push(es.at3(s, i, j));
                        }
                    }
                }
                max_normalize(&mut scores);
                for sc in scores {
                    hist.add(sc as f64);
                }
                // commit argmax-confidence position (Original decoding)
                let mut best = (masked[0], f32::NEG_INFINITY, 0i32);
                for &pos in &masked {
                    let mut probs = out.logits.slice3(s, pos).to_vec();
                    dapd::tensor::softmax_inplace(&mut probs);
                    let (tok, conf) = dapd::tensor::argmax(&probs);
                    if conf > best.1 {
                        best = (pos, conf, tok as i32);
                    }
                }
                tokens[s * l + best.0] = best.2;
            }
        }
    }
    hist
}

fn main() {
    let engine = common::engine();
    let n = common::n_samples(16);
    let taus = [0.005f64, 0.01, 0.02, 0.05, 0.1, 0.2];

    let mut t = Table::new(
        &format!("Fig. 6: CDF of normalized edge scores below tau (multiq, n={n})"),
        &["Model", "tau=0.005", "0.01", "0.02", "0.05", "0.1", "0.2"],
    );
    for model_name in ["sim-llada", "sim-dream"] {
        let hist = collect_hist(&engine, model_name, n);
        let mut row = vec![model_name.to_string()];
        for tau in taus {
            row.push(fmt_f(hist.cdf_below(tau), 3));
        }
        t.row(row);
        // coarse histogram print (10 bins)
        let coarse: Vec<u64> = hist
            .counts
            .chunks(10)
            .map(|c| c.iter().sum())
            .collect();
        println!(
            "{model_name} histogram (deciles of [0,1]): {:?} (total {})",
            coarse, hist.total
        );
    }
    t.print();
    println!(
        "paper shape: mass concentrated near zero; the chosen tau_min sits \
         in the near-zero tail (CDF below it stays small), so early steps \
         only exclude genuinely strong interactions"
    );
}
