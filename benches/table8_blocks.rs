//! Table 8: DAPD under block-wise decoding.
//!
//! Paper reference (HumanEval): DAPD at 1/4/8/16 blocks — accuracy rises
//! slightly with more blocks while TPS falls (restricting the graph to a
//! block surrenders global parallelism); DAPD at 4 blocks still beats the
//! 4-block baselines.  Window 40 here, so we sweep 1/2/4/8.

mod common;

use dapd::decode::Method;
use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len).unwrap();
    let set = EvalSet::load(&engine.meta, "struct").unwrap().take(n);

    let mut t = Table::new(
        &format!("Table 8: block-wise decoding on struct (n={n})"),
        &["Method", "Blocks", "Acc.", "Steps", "TPS"],
    );
    for blocks in [1usize, 2, 4, 8] {
        let mut cfg = common::cfg(Method::DapdStaged);
        cfg.blocks = blocks;
        let r = run_eval(&model, &set, &cfg, "dapd-staged").unwrap();
        t.row(vec![
            "dapd-staged".into(),
            blocks.to_string(),
            fmt_f(r.accuracy_pct(), 1),
            fmt_f(r.avg_steps, 1),
            fmt_f(r.tps, 1),
        ]);
    }
    for method in common::baseline_methods() {
        let mut cfg = common::cfg(method);
        cfg.blocks = 4;
        let r = run_eval(&model, &set, &cfg, method.name()).unwrap();
        t.row(vec![
            method.name().into(),
            "4".into(),
            fmt_f(r.accuracy_pct(), 1),
            fmt_f(r.avg_steps, 1),
            fmt_f(r.tps, 1),
        ]);
    }
    t.print();
    println!(
        "paper shape: DAPD TPS falls as blocks rise (106 -> 34.6 over 1 -> 16 \
         blocks); DAPD at 4 blocks >= 4-block baselines on both axes"
    );
}
