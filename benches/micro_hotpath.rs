//! Micro-benchmarks of the L3 hot path (the §Perf working set):
//! softmax/conf extraction, edge-score gather, graph build, Welsh-Powell,
//! plus one full decode step through the MockModel (no PJRT) and one
//! through a real artifact when available.

mod common;

use dapd::decode::{decode_batch, DecodeConfig, Method};
use dapd::graph::{max_normalize, DepGraph};
use dapd::runtime::{ForwardModel, MockModel};
use dapd::tensor::softmax_inplace;
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::rng::Pcg;

fn main() {
    let mut t = Table::new(
        "L3 hot-path micro-benchmarks",
        &["op", "n", "mean (us)", "sd (us)"],
    );
    let mut rng = Pcg::new(42);

    // softmax over a vocab row x 40 candidates
    let v = 92;
    let rows: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..v).map(|_| rng.f64() as f32 * 8.0).collect())
        .collect();
    let (m, sd) = time_it(
        || {
            for r in &rows {
                let mut buf = r.clone();
                softmax_inplace(&mut buf);
                std::hint::black_box(dapd::tensor::argmax(&buf));
            }
        },
        20,
        200,
    );
    t.row(vec!["softmax+argmax x40".into(), "92".into(), fmt_f(m * 1e6, 1), fmt_f(sd * 1e6, 1)]);

    // edge-score gather + normalize for n candidates out of L=68
    for n in [20usize, 40] {
        let l = 68;
        let es: Vec<f32> = (0..l * l).map(|_| rng.f64() as f32 * 0.02).collect();
        let positions: Vec<usize> = (0..n).map(|i| 28 + i).collect();
        let (m, sd) = time_it(
            || {
                let mut scores = vec![0.0f32; n * n];
                for (ci, &i) in positions.iter().enumerate() {
                    for (cj, &j) in positions.iter().enumerate() {
                        if ci != cj {
                            scores[ci * n + cj] = es[i * l + j];
                        }
                    }
                }
                max_normalize(&mut scores);
                std::hint::black_box(&scores);
            },
            20,
            200,
        );
        t.row(vec![
            "edge gather+norm".into(),
            n.to_string(),
            fmt_f(m * 1e6, 1),
            fmt_f(sd * 1e6, 1),
        ]);
    }

    // graph build + Welsh-Powell at n=40 (the per-step DAPD cost)
    for n in [20usize, 40] {
        let scores: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32).collect();
        let prio: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
        let (m, sd) = time_it(
            || {
                let g = DepGraph::from_scores(n, |i, j| scores[i * n + j], 0.7);
                std::hint::black_box(g.welsh_powell_set(&prio));
            },
            20,
            200,
        );
        t.row(vec![
            "graph build + WP set".into(),
            n.to_string(),
            fmt_f(m * 1e6, 1),
            fmt_f(sd * 1e6, 1),
        ]);
    }

    // full decode on the mock (all strategy machinery, no PJRT)
    let mock = MockModel::new(4, 68, 28, 92);
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![(i as i32 % 9) + 7; 28]).collect();
    let (m, sd) = time_it(
        || {
            let cfg = DecodeConfig::new(Method::DapdStaged);
            std::hint::black_box(decode_batch(&mock, &prompts, &cfg).unwrap());
        },
        3,
        20,
    );
    t.row(vec![
        "decode_batch mock b4 L68".into(),
        "-".into(),
        fmt_f(m * 1e6, 1),
        fmt_f(sd * 1e6, 1),
    ]);

    // one real forward pass, when artifacts exist
    if let Ok(engine) = std::panic::catch_unwind(common::engine) {
        let model = engine.model_for("sim-llada", 4, engine.meta.gen_len).unwrap();
        let tokens = vec![1i32; 4 * model.seq_len()];
        let (m, sd) = time_it(
            || {
                std::hint::black_box(model.forward(&tokens).unwrap());
            },
            3,
            20,
        );
        t.row(vec![
            "PJRT forward b4 L68".into(),
            "-".into(),
            fmt_f(m * 1e6, 1),
            fmt_f(sd * 1e6, 1),
        ]);
    }

    t.print();
    println!("(forward pass should dominate every graph op by >=100x — the");
    println!(" paper's 'negligible graph overhead' claim, quantified)");
}
