//! Micro-benchmarks of the L3 hot path (the §Perf working set):
//! softmax/conf extraction, edge-score gather, graph build, Welsh-Powell,
//! plus one full decode step through the MockModel (no PJRT) and one
//! through a real artifact when available.
//!
//! Environment knobs (CI's bench-smoke job uses both):
//!   DAPD_ITERS=N        timed iterations per op (default 200)
//!   DAPD_BENCH_JSON=f   also write the results as a JSON summary to `f`

mod common;

use dapd::decode::{decode_batch, DecodeConfig, Method};
use dapd::graph::{max_normalize, DepGraph};
use dapd::runtime::{ForwardModel, MockModel};
use dapd::tensor::softmax_inplace;
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::json::Json;
use dapd::util::rng::Pcg;

/// Collects rows for both the printed table and the JSON summary.
struct Recorder {
    table: Table,
    rows: Vec<Json>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            table: Table::new(
                "L3 hot-path micro-benchmarks",
                &["op", "n", "mean (us)", "sd (us)"],
            ),
            rows: Vec::new(),
        }
    }

    fn add(&mut self, op: &str, n: &str, iters: usize, mean_s: f64, sd_s: f64) {
        self.table.row(vec![
            op.to_string(),
            n.to_string(),
            fmt_f(mean_s * 1e6, 1),
            fmt_f(sd_s * 1e6, 1),
        ]);
        let mut row = Json::obj();
        row.set("op", op.into());
        row.set("n", n.into());
        row.set("iters", iters.into());
        row.set("mean_us", (mean_s * 1e6).into());
        row.set("sd_us", (sd_s * 1e6).into());
        self.rows.push(row);
    }

    fn finish(self) {
        self.table.print();
        if let Ok(path) = std::env::var("DAPD_BENCH_JSON") {
            let mut out = Json::obj();
            out.set("bench", "micro_hotpath".into());
            out.set("rows", Json::Arr(self.rows));
            match std::fs::write(&path, out.dump()) {
                Ok(()) => println!("wrote JSON summary to {path}"),
                Err(e) => eprintln!("failed writing {path}: {e}"),
            }
        }
    }
}

fn main() {
    let iters: usize = std::env::var("DAPD_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let warmup = (iters / 10).max(1);
    // the full-decode ops are ~100x heavier per iteration
    let heavy_iters = (iters / 10).max(1);
    let heavy_warmup = (warmup / 5).max(1);

    let mut rec = Recorder::new();
    let mut rng = Pcg::new(42);

    // softmax over a vocab row x 40 candidates
    let v = 92;
    let rows: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..v).map(|_| rng.f64() as f32 * 8.0).collect())
        .collect();
    let (m, sd) = time_it(
        || {
            for r in &rows {
                let mut buf = r.clone();
                softmax_inplace(&mut buf);
                std::hint::black_box(dapd::tensor::argmax(&buf));
            }
        },
        warmup,
        iters,
    );
    rec.add("softmax+argmax x40", "92", iters, m, sd);

    // edge-score gather + normalize for n candidates out of L=68
    for n in [20usize, 40] {
        let l = 68;
        let es: Vec<f32> = (0..l * l).map(|_| rng.f64() as f32 * 0.02).collect();
        let positions: Vec<usize> = (0..n).map(|i| 28 + i).collect();
        let (m, sd) = time_it(
            || {
                let mut scores = vec![0.0f32; n * n];
                for (ci, &i) in positions.iter().enumerate() {
                    for (cj, &j) in positions.iter().enumerate() {
                        if ci != cj {
                            scores[ci * n + cj] = es[i * l + j];
                        }
                    }
                }
                max_normalize(&mut scores);
                std::hint::black_box(&scores);
            },
            warmup,
            iters,
        );
        rec.add("edge gather+norm", &n.to_string(), iters, m, sd);
    }

    // graph build + Welsh-Powell at n=40 (the per-step DAPD cost)
    for n in [20usize, 40] {
        let scores: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32).collect();
        let prio: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
        let (m, sd) = time_it(
            || {
                let g = DepGraph::from_scores(n, |i, j| scores[i * n + j], 0.7);
                std::hint::black_box(g.welsh_powell_set(&prio));
            },
            warmup,
            iters,
        );
        rec.add("graph build + WP set", &n.to_string(), iters, m, sd);
    }

    // full decode on the mock (all strategy machinery, no PJRT)
    let mock = MockModel::new(4, 68, 28, 92);
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![(i as i32 % 9) + 7; 28]).collect();
    let (m, sd) = time_it(
        || {
            let cfg = DecodeConfig::new(Method::DapdStaged);
            std::hint::black_box(decode_batch(&mock, &prompts, &cfg).unwrap());
        },
        heavy_warmup,
        heavy_iters,
    );
    rec.add("decode_batch mock b4 L68", "-", heavy_iters, m, sd);

    // one real forward pass, when artifacts exist
    if let Ok(engine) = std::panic::catch_unwind(common::engine) {
        let model = engine.model_for("sim-llada", 4, engine.meta.gen_len).unwrap();
        let tokens = vec![1i32; 4 * model.seq_len()];
        let (m, sd) = time_it(
            || {
                std::hint::black_box(model.forward(&tokens).unwrap());
            },
            heavy_warmup,
            heavy_iters,
        );
        rec.add("PJRT forward b4 L68", "-", heavy_iters, m, sd);
    }

    rec.finish();
    println!("(forward pass should dominate every graph op by >=100x — the");
    println!(" paper's 'negligible graph overhead' claim, quantified)");
}
