//! Micro-benchmarks of the L3 hot path (the §Perf working set):
//! softmax/conf extraction, edge-score gather, graph build, Welsh-Powell,
//! plus one full decode step through the MockModel (no PJRT) and one
//! through a real artifact when available.
//!
//! Environment knobs (CI's bench-smoke job uses all three):
//!   DAPD_ITERS=N        timed iterations per op (default 200)
//!   DAPD_BENCH_JSON=f   also write the results as a JSON summary to `f`
//!   DAPD_MIN_KERNEL_SPEEDUP=x  gate on the fused-native vs seed-scalar
//!                       feature-derivation section (default 2.0 on the
//!                       AVX2 tier; CI relaxes to 1.1; skipped when the
//!                       native tier is not avx2)

mod common;

use dapd::decode::{decode_batch, DecodeConfig, Method};
use dapd::graph::{max_normalize, DepGraph};
use dapd::runtime::{ForwardModel, MockModel};
use dapd::tensor::kernels::{self, Backend};
use dapd::tensor::softmax_inplace;
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::json::Json;
use dapd::util::rng::Pcg;

/// Collects rows for both the printed table and the JSON summary.
struct Recorder {
    table: Table,
    rows: Vec<Json>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            table: Table::new(
                "L3 hot-path micro-benchmarks",
                &["op", "n", "mean (us)", "sd (us)"],
            ),
            rows: Vec::new(),
        }
    }

    fn add(&mut self, op: &str, n: &str, iters: usize, mean_s: f64, sd_s: f64) {
        self.table.row(vec![
            op.to_string(),
            n.to_string(),
            fmt_f(mean_s * 1e6, 1),
            fmt_f(sd_s * 1e6, 1),
        ]);
        let mut row = Json::obj();
        row.set("op", op.into());
        row.set("n", n.into());
        row.set("iters", iters.into());
        row.set("mean_us", (mean_s * 1e6).into());
        row.set("sd_us", (sd_s * 1e6).into());
        self.rows.push(row);
    }

    fn finish(self, extras: Vec<(&'static str, Json)>) {
        self.table.print();
        if let Ok(path) = std::env::var("DAPD_BENCH_JSON") {
            let mut out = Json::obj();
            out.set("bench", "micro_hotpath".into());
            for (k, v) in extras {
                out.set(k, v);
            }
            out.set("rows", Json::Arr(self.rows));
            match std::fs::write(&path, out.dump()) {
                Ok(()) => println!("wrote JSON summary to {path}"),
                Err(e) => eprintln!("failed writing {path}: {e}"),
            }
        }
    }
}

fn main() {
    let iters: usize = std::env::var("DAPD_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let warmup = (iters / 10).max(1);
    // the full-decode ops are ~100x heavier per iteration
    let heavy_iters = (iters / 10).max(1);
    let heavy_warmup = (warmup / 5).max(1);

    let mut rec = Recorder::new();
    let mut rng = Pcg::new(42);

    // softmax over a vocab row x 40 candidates
    let v = 92;
    let rows: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..v).map(|_| rng.f64() as f32 * 8.0).collect())
        .collect();
    let (m, sd) = time_it(
        || {
            for r in &rows {
                let mut buf = r.clone();
                softmax_inplace(&mut buf);
                std::hint::black_box(dapd::tensor::argmax(&buf));
            }
        },
        warmup,
        iters,
    );
    rec.add("softmax+argmax x40", "92", iters, m, sd);

    // edge-score gather + normalize for n candidates out of L=68
    for n in [20usize, 40] {
        let l = 68;
        let es: Vec<f32> = (0..l * l).map(|_| rng.f64() as f32 * 0.02).collect();
        let positions: Vec<usize> = (0..n).map(|i| 28 + i).collect();
        let (m, sd) = time_it(
            || {
                let mut scores = vec![0.0f32; n * n];
                for (ci, &i) in positions.iter().enumerate() {
                    for (cj, &j) in positions.iter().enumerate() {
                        if ci != cj {
                            scores[ci * n + cj] = es[i * l + j];
                        }
                    }
                }
                max_normalize(&mut scores);
                std::hint::black_box(&scores);
            },
            warmup,
            iters,
        );
        rec.add("edge gather+norm", &n.to_string(), iters, m, sd);
    }

    // graph build + Welsh-Powell at n=40 (the per-step DAPD cost)
    for n in [20usize, 40] {
        let scores: Vec<f32> = (0..n * n).map(|_| rng.f64() as f32).collect();
        let prio: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
        let (m, sd) = time_it(
            || {
                let g = DepGraph::from_scores(n, |i, j| scores[i * n + j], 0.7);
                std::hint::black_box(g.welsh_powell_set(&prio));
            },
            warmup,
            iters,
        );
        rec.add("graph build + WP set", &n.to_string(), iters, m, sd);
    }

    // ---- kernel layer: scalar reference vs runtime-dispatched native ---
    // serving-shape rows: 40 candidates x vocab 256, with prev-step
    // distributions so the fused kernel's KL term is exercised
    let kv = 256usize;
    let logit_rows: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..kv).map(|_| rng.f64() as f32 * 8.0).collect())
        .collect();
    let prev_rows: Vec<Vec<f32>> = logit_rows
        .iter()
        .map(|r| {
            let mut p = r.clone();
            kernels::softmax_inplace(Backend::Scalar, &mut p);
            p
        })
        .collect();
    let mut buf = vec![0.0f32; kv];

    // the feature-derivation section: the seed's four-pass sequence
    // (softmax + argmax + entropy + KL, scalar) vs one fused native call
    let (t_seed, sd_seed) = time_it(
        || {
            for (r, q) in logit_rows.iter().zip(&prev_rows) {
                buf.copy_from_slice(r);
                kernels::softmax_inplace(Backend::Scalar, &mut buf);
                let am = kernels::argmax(Backend::Scalar, &buf);
                let h = kernels::entropy(Backend::Scalar, &buf);
                let kl = kernels::kl_div(Backend::Scalar, &buf, q);
                std::hint::black_box((am, h, kl));
            }
        },
        warmup,
        iters,
    );
    rec.add("feature derive x40 [seed-scalar]", &kv.to_string(), iters, t_seed, sd_seed);
    let (t_fused, sd_fused) = time_it(
        || {
            for (r, q) in logit_rows.iter().zip(&prev_rows) {
                buf.copy_from_slice(r);
                std::hint::black_box(kernels::softmax_stats(
                    Backend::Native,
                    &mut buf,
                    Some(q.as_slice()),
                ));
            }
        },
        warmup,
        iters,
    );
    rec.add("feature derive x40 [native-fused]", &kv.to_string(), iters, t_fused, sd_fused);
    let kernel_speedup = t_seed / t_fused;

    // per-kernel scalar-vs-native rows
    for backend in [Backend::Scalar, Backend::Native] {
        let tag = backend.name();
        let (m, sd) = time_it(
            || {
                for q in &prev_rows {
                    std::hint::black_box(kernels::argmax(backend, q));
                }
            },
            warmup,
            iters,
        );
        rec.add(&format!("kernel argmax x40 [{tag}]"), &kv.to_string(), iters, m, sd);
        let (m, sd) = time_it(
            || {
                for q in &prev_rows {
                    std::hint::black_box(kernels::sum(backend, q));
                }
            },
            warmup,
            iters,
        );
        rec.add(&format!("kernel sum x40 [{tag}]"), &kv.to_string(), iters, m, sd);
        let (m, sd) = time_it(
            || {
                for q in &prev_rows {
                    std::hint::black_box(kernels::entropy(backend, q));
                }
            },
            warmup,
            iters,
        );
        rec.add(&format!("kernel entropy x40 [{tag}]"), &kv.to_string(), iters, m, sd);
        let (m, sd) = time_it(
            || {
                for (r, q) in prev_rows.iter().zip(prev_rows.iter().rev()) {
                    std::hint::black_box(kernels::kl_div(backend, r, q));
                }
            },
            warmup,
            iters,
        );
        rec.add(&format!("kernel kl_div x40 [{tag}]"), &kv.to_string(), iters, m, sd);
    }

    // full decode on the mock (all strategy machinery, no PJRT)
    let mock = MockModel::new(4, 68, 28, 92);
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![(i as i32 % 9) + 7; 28]).collect();
    let (m, sd) = time_it(
        || {
            let cfg = DecodeConfig::new(Method::DapdStaged);
            std::hint::black_box(decode_batch(&mock, &prompts, &cfg).unwrap());
        },
        heavy_warmup,
        heavy_iters,
    );
    rec.add("decode_batch mock b4 L68", "-", heavy_iters, m, sd);

    // one real forward pass, when artifacts exist
    if let Ok(engine) = std::panic::catch_unwind(common::engine) {
        let model = engine.model_for("sim-llada", 4, engine.meta.gen_len).unwrap();
        let tokens = vec![1i32; 4 * model.seq_len()];
        let (m, sd) = time_it(
            || {
                std::hint::black_box(model.forward(&tokens).unwrap());
            },
            heavy_warmup,
            heavy_iters,
        );
        rec.add("PJRT forward b4 L68", "-", heavy_iters, m, sd);
    }

    let isa = kernels::active_isa(Backend::Native);
    let mut extras: Vec<(&'static str, Json)> = vec![
        ("kernel_isa", isa.into()),
        ("kernel_feature_speedup", kernel_speedup.into()),
    ];
    let gate: f64 = match std::env::var("DAPD_MIN_KERNEL_SPEEDUP") {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            eprintln!(
                "warning: DAPD_MIN_KERNEL_SPEEDUP='{v}' is not a number; \
                 using the strict default 2.0"
            );
            2.0
        }),
        Err(_) => 2.0,
    };
    extras.push(("kernel_speedup_gate", gate.into()));
    rec.finish(extras);
    println!(
        "\nkernel layer: native tier = {isa}; feature-derivation \
         fused-native vs seed-scalar speedup = {kernel_speedup:.2}x \
         (gate: {gate:.2}x on avx2)"
    );
    if isa == "avx2" {
        assert!(
            kernel_speedup >= gate,
            "fused native kernels must reach >= {gate:.2}x the seed scalar \
             feature derivation on the AVX2 tier (got {kernel_speedup:.2}x; \
             relax via DAPD_MIN_KERNEL_SPEEDUP)"
        );
    } else {
        println!("(kernel speedup gate skipped: native tier is {isa}, the gate targets avx2)");
    }
    println!("(forward pass should dominate every graph op by >=100x — the");
    println!(" paper's 'negligible graph overhead' claim, quantified)");
}
