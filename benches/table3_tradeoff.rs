//! Table 3 / Fig. 3: the main accuracy-steps trade-off across task
//! families and both simulated dLLMs.
//!
//! Protocol mirrors the paper: on sim-llada the training-free baselines
//! run with 4-block decoding (their single-block variants collapse from
//! EOS overflow — Table 5 shows that), while DAPD runs single-block.
//! On sim-dream everything is single-block.
//!
//! Task mapping (DESIGN.md): struct ~ HumanEval/MBPP, arith ~ GSM8K/
//! Math500, constraint ~ IFEval, plus multiq.

mod common;

use dapd::decode::Method;
use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let tasks = ["struct", "arith", "constraint", "multiq"];

    for model_name in ["sim-llada", "sim-dream"] {
        let model = engine.model_for(model_name, 8, engine.meta.gen_len).unwrap();
        let mut t = Table::new(
            &format!("Table 3: accuracy-steps on {model_name} (n={n}/task)"),
            &["Task", "Method", "Blocks", "Acc.", "Steps", "TPS"],
        );
        for task in tasks {
            let set = EvalSet::load(&engine.meta, task).unwrap().take(n);
            for method in common::baseline_methods() {
                let mut cfg = common::cfg(method);
                // paper protocol: block decoding for LLaDA baselines only
                cfg.blocks = if model_name == "sim-llada" { 4 } else { 1 };
                let r = run_eval(&model, &set, &cfg, method.name()).unwrap();
                t.row(vec![
                    task.into(),
                    method.name().into(),
                    cfg.blocks.to_string(),
                    fmt_f(r.accuracy_pct(), 1),
                    fmt_f(r.avg_steps, 1),
                    fmt_f(r.tps, 1),
                ]);
            }
            for method in common::dapd_methods() {
                let cfg = common::cfg(method); // single-block
                let r = run_eval(&model, &set, &cfg, method.name()).unwrap();
                t.row(vec![
                    task.into(),
                    method.name().into(),
                    "1".into(),
                    fmt_f(r.accuracy_pct(), 1),
                    fmt_f(r.avg_steps, 1),
                    fmt_f(r.tps, 1),
                ]);
            }
            // token-by-token reference
            let r = run_eval(&model, &set, &common::cfg(Method::Original), "original").unwrap();
            t.row(vec![
                task.into(),
                "original".into(),
                "1".into(),
                fmt_f(r.accuracy_pct(), 1),
                fmt_f(r.avg_steps, 1),
                fmt_f(r.tps, 1),
            ]);
        }
        t.print();
    }
    println!(
        "paper shape: DAPD occupies the upper-left (matched accuracy at \
         ~2x fewer steps than block-wise baselines; DAPD-Direct fewest steps)"
    );
}
