//! Table 7: generation-length scaling of DAPD (accuracy / steps / TPS).
//!
//! Paper sweeps 256 -> 1024 upward; our learned positional table caps the
//! window at the training length, so this testbed sweeps the compiled
//! windows {16, 28, 40} (documented inversion: same question — does the
//! O(L^2) graph overhead erode TPS as the window grows — asked across the
//! lengths this model supports).  Paper shape: TPS stays roughly flat;
//! steps grow sublinearly with window size.

mod common;

use dapd::decode::Method;
use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let tasks = ["struct", "arith"];
    let gens = [16usize, 28, 40];

    let mut t = Table::new(
        &format!("Table 7: DAPD-Staged across generation windows (n={n}/cell)"),
        &["Task", "GenLen", "Acc.", "Steps", "TPS"],
    );
    for task in tasks {
        let set = EvalSet::load(&engine.meta, task).unwrap().take(n);
        for gen in gens {
            let model = engine.model_for("sim-llada", 4, gen).unwrap();
            let r = run_eval(&model, &set, &common::cfg(Method::DapdStaged), "dapd-staged")
                .unwrap();
            t.row(vec![
                task.into(),
                gen.to_string(),
                fmt_f(r.accuracy_pct(), 1),
                fmt_f(r.avg_steps, 1),
                fmt_f(r.tps, 1),
            ]);
        }
    }
    t.print();
    println!(
        "paper shape: steps grow sublinearly with window; TPS stays stable \
         (graph work doesn't dominate); short windows truncate long answers \
         (struct answers need up to 18 tokens -> gen 16 must lose accuracy)"
    );
}
