//! Table 4 / Fig. 4: the ParallelBench analogue — tasks that stress
//! parallel decoding under strong inter-token dependencies.
//!
//! Task mapping: copy/rev/sort ~ Waiting Line; latin ~ Puzzle;
//! para ~ Paraphrase; w2s ~ Words->Sentence.  Paper shape: DAPD reaches
//! similar scores at visibly fewer steps; copy-like tasks parallelize
//! hardest (weak coupling), sort/puzzle stay coupled.

mod common;

use dapd::decode::Method;
use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::{EvalSet, PBENCH_TASKS};

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len).unwrap();

    let methods = [
        Method::FastDllm,
        Method::EbSampler,
        Method::Klass,
        Method::DapdStaged,
        Method::DapdDirect,
    ];
    let mut t = Table::new(
        &format!("Table 4: ParallelBench analogue on sim-llada (n={n}/task)"),
        &["Task", "Method", "Score", "Steps"],
    );
    for task in PBENCH_TASKS {
        let set = EvalSet::load(&engine.meta, task).unwrap().take(n);
        for method in methods {
            // ParallelBench protocol: single block, default hyperparams
            let r = run_eval(&model, &set, &common::cfg(method), method.name()).unwrap();
            t.row(vec![
                task.into(),
                method.name().into(),
                fmt_f(r.accuracy_pct(), 1),
                fmt_f(r.avg_steps, 1),
            ]);
        }
    }
    t.print();
    println!(
        "paper (Tab. 4): DAPD-Staged wins Words->Sentence (88.2 vs 78.2) \
         with fewest steps; scores comparable elsewhere at lower steps"
    );
}
