//! Table 6: end-to-end throughput through the full serving stack
//! (coordinator + dynamic batcher), tokens per second.
//!
//! Paper reference (HumanEval, LLaDA):
//!   DAPD 106.0 TPS / Fast-dLLM 51.4 / EB 39.2 / KLASS 25.6 / Original
//!   20.4 — TPS tracks 1/steps because graph work is negligible next to
//!   forward passes.  The same relationship should hold here.

mod common;

use std::time::{Duration, Instant};

use dapd::coordinator::Coordinator;
use dapd::decode::Method;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::{scorer, EvalSet};

fn main() {
    let engine: &'static dapd::runtime::Engine = Box::leak(Box::new(common::engine()));
    let n = common::n_samples(32);
    let set = EvalSet::load(&engine.meta, "struct").unwrap().take(n);

    let methods = [
        Method::DapdStaged,
        Method::FastDllm,
        Method::EbSampler,
        Method::Klass,
        Method::Original,
    ];
    let mut t = Table::new(
        &format!("Table 6: end-to-end TPS via coordinator (struct, n={n}, batch 4)"),
        &["Method", "Acc.", "Steps", "TPS", "p95 latency (s)"],
    );
    for method in methods {
        // fresh coordinator per method so metrics are isolated
        let model = engine.model_for("sim-llada", 4, engine.meta.gen_len).unwrap();
        let (coord, handle) = Coordinator::start(model, Duration::from_millis(2), 256);
        let t0 = Instant::now();
        let rxs: Vec<_> = set
            .instances
            .iter()
            .map(|inst| coord.submit(inst.prompt.clone(), common::cfg(method)).unwrap())
            .collect();
        let mut acc = 0.0;
        let mut tokens = 0usize;
        for (inst, rx) in set.instances.iter().zip(rxs) {
            let resp = rx.recv().unwrap();
            acc += scorer::score("struct", &resp.gen, &inst.expect, &inst.spec);
            tokens += resp.gen.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (_, p95) = coord.metrics.latency_p50_p95();
        t.row(vec![
            method.name().into(),
            fmt_f(100.0 * acc / n as f64, 1),
            fmt_f(coord.metrics.mean_steps(), 1),
            fmt_f(tokens as f64 / wall, 1),
            fmt_f(p95, 2),
        ]);
        coord.shutdown();
        handle.join().unwrap();
    }
    t.print();
    println!("paper shape: TPS ordering DAPD > Fast-dLLM > EB > KLASS > Original,");
    println!("with TPS ~ c / steps (graph overhead negligible vs forwards)");
}
