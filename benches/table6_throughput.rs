//! Table 6: end-to-end throughput through the full serving stack
//! (coordinator worker pool + continuous batcher), tokens per second.
//!
//! Paper reference (HumanEval, LLaDA):
//!   DAPD 106.0 TPS / Fast-dLLM 51.4 / EB 39.2 / KLASS 25.6 / Original
//!   20.4 — TPS tracks 1/steps because graph work is negligible next to
//!   forward passes.  The same relationship should hold here.
//!
//! Two sections: worker-pool scaling on the mock model (artifact-free,
//! always runs), then the paper's per-method table through a real PJRT
//! artifact when `make artifacts` has been run.

mod common;

use std::time::{Duration, Instant};

use dapd::coordinator::{Coordinator, PoolOptions};
use dapd::decode::{DecodeConfig, Method};
use dapd::runtime::{MockModel, ModelPool};
use dapd::util::bench::{fmt_f, Table};
use dapd::util::rng::Pcg;
use dapd::workload::{scorer, EvalSet};

/// Closed-loop TPS through pools of growing size on the mock model: the
/// aggregate-throughput half of the Table 6 story (the coordinator must
/// scale with cores, not just with fewer steps).
fn pool_scaling(n: usize) {
    let pool = ModelPool::mock(MockModel::new(4, 68, 28, 92));
    let mut rng = Pcg::new(13);
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|_| (0..28).map(|_| (2 + rng.below(90)) as i32).collect())
        .collect();

    let mut t = Table::new(
        &format!("Worker-pool scaling on the mock model (closed loop, n={n})"),
        &["workers", "wall (s)", "tok/s", "speedup"],
    );
    let mut base_tput = 0.0f64;
    for workers in [1usize, 2, 4] {
        let opts = PoolOptions {
            workers,
            batch_wait: Duration::from_millis(2),
            queue_cap: n + 8,
            ..PoolOptions::default()
        };
        let (coord, handles) = Coordinator::start_pool(&pool, &opts).unwrap();
        let t0 = Instant::now();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                coord
                    .submit(p.clone(), DecodeConfig::new(Method::DapdStaged))
                    .unwrap()
            })
            .collect();
        let mut tokens = 0usize;
        for rx in rxs {
            tokens += rx.recv().unwrap().unwrap().gen.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        coord.shutdown();
        handles.join();
        let tput = tokens as f64 / wall;
        if workers == 1 {
            base_tput = tput;
        }
        t.row(vec![
            workers.to_string(),
            fmt_f(wall, 2),
            fmt_f(tput, 1),
            fmt_f(tput / base_tput, 2),
        ]);
    }
    t.print();
}

/// The paper's per-method TPS table over a real artifact.
fn paper_table(engine: dapd::runtime::Engine) {
    let n = common::n_samples(32);
    let set = EvalSet::load(&engine.meta, "struct").unwrap().take(n);

    let methods = [
        Method::DapdStaged,
        Method::FastDllm,
        Method::EbSampler,
        Method::Klass,
        Method::Original,
    ];
    let mut t = Table::new(
        &format!("Table 6: end-to-end TPS via coordinator (struct, n={n}, batch 4)"),
        &["Method", "Acc.", "Steps", "TPS", "p95 latency (s)"],
    );
    for method in methods {
        // fresh coordinator per method so metrics are isolated
        let model = engine.model_for("sim-llada", 4, engine.meta.gen_len).unwrap();
        let (coord, handle) = Coordinator::start(model, Duration::from_millis(2), 256);
        let t0 = Instant::now();
        let rxs: Vec<_> = set
            .instances
            .iter()
            .map(|inst| coord.submit(inst.prompt.clone(), common::cfg(method)).unwrap())
            .collect();
        let mut acc = 0.0;
        let mut tokens = 0usize;
        for (inst, rx) in set.instances.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            acc += scorer::score("struct", &resp.gen, &inst.expect, &inst.spec);
            tokens += resp.gen.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        let (_, p95, _) = coord.metrics.latency_percentiles();
        t.row(vec![
            method.name().into(),
            fmt_f(100.0 * acc / n as f64, 1),
            fmt_f(coord.metrics.mean_steps(), 1),
            fmt_f(tokens as f64 / wall, 1),
            fmt_f(p95, 2),
        ]);
        coord.shutdown();
        handle.join().unwrap();
    }
    t.print();
    println!("paper shape: TPS ordering DAPD > Fast-dLLM > EB > KLASS > Original,");
    println!("with TPS ~ c / steps (graph overhead negligible vs forwards)");
}

fn main() {
    pool_scaling(common::n_samples(32));
    match std::panic::catch_unwind(common::engine) {
        Ok(engine) => paper_table(engine),
        Err(_) => println!("(artifacts unavailable — skipping the PJRT per-method table)"),
    }
}
