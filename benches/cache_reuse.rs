//! Cache-reuse bench: steps/s for cached vs uncached decode, per method,
//! plus the cross-request prefix-cache section.
//!
//! For every method the same prompts are decoded (a) uncached — the seed
//! path — and (b) through the compute-reuse subsystem at
//! `refresh_every` in {1, 4, 8}.  The bench *asserts* the subsystem's
//! contract:
//!
//!   * cached output is token-for-token identical to uncached at every
//!     refresh period (the mock backend is deterministic and the loop
//!     only reads recomputed positions);
//!   * at `refresh_every >= 4`, cached decode reaches >= 1.5x steps/s.
//!
//! A third section drives a *mixed* board: two requests decode from step
//! 0 while two same-prompt repeats are admitted mid-flight with
//! prefix-cache hits.  The hit rows are spliced into the windowed
//! forward (never forcing a full one), and the section asserts both
//! token identity against the uncached run of the same admission
//! schedule and the `DAPD_MIN_SPEEDUP` steps/s gate.
//!
//! Environment knobs (CI's bench-smoke job uses them):
//!   DAPD_ITERS=N          timed decodes per mode (default 6)
//!   DAPD_BENCH_JSON=f     also write the results as a JSON summary to `f`
//!   DAPD_MIN_SPEEDUP=x.y  speedup gate at refresh_every=4 and on the
//!                         mixed-board section (default 1.5; the
//!                         token-identity asserts always run)

use std::sync::Arc;

use dapd::cache::{CacheConfig, CacheStats, PrefixCache, PrefixHandle};
use dapd::decode::{DecodeConfig, DecodeOutcome, Method, SlotBatch};
use dapd::runtime::MockModel;
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::json::Json;
use dapd::util::rng::Pcg;

/// One full decode of `prompts` through a fresh `SlotBatch`; returns the
/// outcomes, the compute-reuse counters and the board-step count.
fn decode_once(
    model: &MockModel,
    cfg: &DecodeConfig,
    cache: &CacheConfig,
    prefix: Option<PrefixHandle>,
    prompts: &[Vec<i32>],
) -> (Vec<DecodeOutcome>, CacheStats, usize) {
    let mut sb = SlotBatch::with_cache(model, cfg, cache, prefix).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        sb.admit(i as u64, p).unwrap();
    }
    let mut outs: Vec<Option<DecodeOutcome>> = (0..prompts.len()).map(|_| None).collect();
    let mut board_steps = 0usize;
    while sb.occupied() > 0 {
        board_steps += 1;
        for (id, o) in sb.step().unwrap() {
            outs[id as usize] = Some(o);
        }
    }
    (
        outs.into_iter().map(|o| o.unwrap()).collect(),
        sb.cache_stats(),
        board_steps,
    )
}

/// One full decode under a fixed admission schedule: request `i` is
/// admitted at board-step `admit_at[i]` (as soon as a slot frees).  The
/// schedule depends only on step counts, which are identical between
/// cached and uncached runs (the identity contract), so both runs see
/// the same board compositions.
fn decode_scheduled(
    model: &MockModel,
    cfg: &DecodeConfig,
    cache: &CacheConfig,
    prefix: Option<PrefixHandle>,
    prompts: &[Vec<i32>],
    admit_at: &[usize],
) -> (Vec<DecodeOutcome>, CacheStats, usize) {
    assert_eq!(prompts.len(), admit_at.len());
    let mut sb = SlotBatch::with_cache(model, cfg, cache, prefix).unwrap();
    let mut outs: Vec<Option<DecodeOutcome>> = (0..prompts.len()).map(|_| None).collect();
    let mut next = 0usize;
    let mut board_steps = 0usize;
    loop {
        while next < prompts.len() && admit_at[next] <= board_steps && sb.has_free_slot() {
            sb.admit(next as u64, &prompts[next]).unwrap();
            next += 1;
        }
        if sb.occupied() == 0 {
            if next >= prompts.len() {
                break;
            }
            board_steps += 1; // idle tick until the next admission
            continue;
        }
        board_steps += 1;
        for (id, o) in sb.step().unwrap() {
            outs[id as usize] = Some(o);
        }
    }
    (
        outs.into_iter().map(|o| o.unwrap()).collect(),
        sb.cache_stats(),
        board_steps,
    )
}

/// One printed/JSON result row.
struct Row {
    method: Method,
    mode: String,
    mean_s: f64,
    steps: usize,
    speedup: f64,
    frac: f64,
}

fn add_row(table: &mut Table, rows: &mut Vec<Json>, row: Row) {
    let steps_per_s = row.steps as f64 / row.mean_s;
    table.row(vec![
        row.method.name().to_string(),
        row.mode.clone(),
        fmt_f(row.mean_s * 1e3, 2),
        fmt_f(steps_per_s, 0),
        fmt_f(row.speedup, 2),
        fmt_f(row.frac, 3),
    ]);
    let mut r = Json::obj();
    r.set("method", row.method.name().into());
    r.set("mode", row.mode.as_str().into());
    r.set("mean_ms", (row.mean_s * 1e3).into());
    r.set("steps_per_s", steps_per_s.into());
    r.set("speedup", row.speedup.into());
    r.set("compute_frac", row.frac.into());
    rows.push(r);
}

fn assert_identical(want: &[DecodeOutcome], got: &[DecodeOutcome], ctx: &str) {
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.gen, g.gen, "{ctx}: sample {i} tokens diverged");
        assert_eq!(w.steps, g.steps, "{ctx}: sample {i} NFE diverged");
        assert_eq!(
            w.per_step_commits, g.per_step_commits,
            "{ctx}: sample {i} trajectory diverged"
        );
    }
}

fn main() {
    let iters: usize = std::env::var("DAPD_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);

    // long prompt, short-ish generation window: the serving shape where
    // frozen prompt rows pay off most (APD's observation)
    let model = MockModel::new(4, 128, 96, 256);
    let mut rng = Pcg::new(17);
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..96).map(|_| (2 + rng.below(254)) as i32).collect())
        .collect();

    let off = CacheConfig::default();
    let mut table = Table::new(
        "Cache reuse: steps/s cached vs uncached (mock, b=4 L=128 P=96 V=256)",
        &["method", "mode", "ms/decode", "steps/s", "speedup", "compute_frac"],
    );
    let mut rows: Vec<Json> = Vec::new();

    let mut min_speedup_at_4 = f64::INFINITY;
    for method in Method::all() {
        let cfg = DecodeConfig::new(method);
        let (base_out, _, board_steps) = decode_once(&model, &cfg, &off, None, &prompts);
        let (t_off, _) = time_it(
            || {
                std::hint::black_box(decode_once(&model, &cfg, &off, None, &prompts));
            },
            1,
            iters,
        );
        add_row(
            &mut table,
            &mut rows,
            Row {
                method,
                mode: "uncached".into(),
                mean_s: t_off,
                steps: board_steps,
                speedup: 1.0,
                frac: 1.0,
            },
        );

        for refresh_every in [1usize, 4, 8] {
            let cache = CacheConfig {
                enabled: true,
                refresh_every,
                epsilon: 0.0,
                prefix_lru_cap: 0,
            };
            let (out, stats, steps) = decode_once(&model, &cfg, &cache, None, &prompts);
            assert_eq!(steps, board_steps, "{method:?}: cached board-step count");
            assert_identical(
                &base_out,
                &out,
                &format!("{} refresh_every={refresh_every}", method.name()),
            );
            let (t_on, _) = time_it(
                || {
                    std::hint::black_box(decode_once(&model, &cfg, &cache, None, &prompts));
                },
                1,
                iters,
            );
            let speedup = t_off / t_on;
            if refresh_every == 4 {
                min_speedup_at_4 = min_speedup_at_4.min(speedup);
            }
            add_row(
                &mut table,
                &mut rows,
                Row {
                    method,
                    mode: format!("refresh={refresh_every}"),
                    mean_s: t_on,
                    steps,
                    speedup,
                    frac: stats.compute_frac(),
                },
            );
        }
    }
    table.print();

    // ---- cross-request prefix cache: same prompt, repeated ------------
    let solo = MockModel::new(1, 128, 96, 256);
    let prompt: Vec<i32> = (0..96).map(|i| 2 + (i as i32 * 5) % 250).collect();
    let cfg = DecodeConfig::new(Method::DapdStaged);
    let cache = CacheConfig {
        enabled: true,
        refresh_every: 4,
        epsilon: 0.0,
        prefix_lru_cap: 8,
    };
    let requests = 8usize;
    let (base_out, _, _) = decode_once(&solo, &cfg, &off, None, &[prompt.clone()]);
    let run_repeats = |prefix_cap: usize| -> (f64, u64, u64) {
        let pc = Arc::new(PrefixCache::new(prefix_cap));
        let handle = PrefixHandle::new(Arc::clone(&pc), "bench-solo");
        let t0 = std::time::Instant::now();
        let mut served = 0u64;
        for _ in 0..requests {
            let (out, stats, _) = decode_once(
                &solo,
                &cfg,
                &cache,
                if prefix_cap > 0 {
                    Some(handle.clone())
                } else {
                    None
                },
                &[prompt.clone()],
            );
            assert_identical(&base_out, &out, "prefix repeat");
            served += stats.prefix_served_steps;
        }
        (t0.elapsed().as_secs_f64(), served, pc.hits())
    };
    let (t_noprefix, served0, _) = run_repeats(0);
    let (t_prefix, served, hits) = run_repeats(8);
    assert_eq!(served0, 0);
    assert_eq!(
        served,
        (requests - 1) as u64,
        "every repeat request must skip its first forward"
    );
    assert_eq!(hits, (requests - 1) as u64);
    let mut prefix_table = Table::new(
        &format!("Prefix cache: {requests} identical requests (b=1)"),
        &["mode", "total ms", "first-steps served from cache"],
    );
    prefix_table.row(vec![
        "no prefix".into(),
        fmt_f(t_noprefix * 1e3, 2),
        "0".into(),
    ]);
    prefix_table.row(vec![
        "prefix lru".into(),
        fmt_f(t_prefix * 1e3, 2),
        served.to_string(),
    ]);
    prefix_table.print();

    // ---- mixed boards: prefix hits spliced into the windowed forward --
    // two cold requests decode from step 0; two repeats of already-seen
    // prompts are admitted mid-flight, so the board mixes step-0 hits
    // with in-flight slots — the case that used to force full forwards.
    let mixed_model = MockModel::new(4, 128, 96, 256);
    let mixed_prompts: Vec<Vec<i32>> = {
        let mut rng = Pcg::new(29);
        let a: Vec<i32> = (0..96).map(|_| (2 + rng.below(254)) as i32).collect();
        let b: Vec<i32> = (0..96).map(|_| (2 + rng.below(254)) as i32).collect();
        // requests 2 and 3 repeat the first two prompts -> prefix hits
        vec![a.clone(), b.clone(), a, b]
    };
    let admit_at = [0usize, 0, 3, 5];
    let mixed_cfg = DecodeConfig::new(Method::DapdStaged);
    let mixed_cache = CacheConfig {
        enabled: true,
        refresh_every: 4,
        epsilon: 0.0,
        prefix_lru_cap: 8,
    };
    let (base_mixed, _, mixed_steps) = decode_scheduled(
        &mixed_model,
        &mixed_cfg,
        &off,
        None,
        &mixed_prompts,
        &admit_at,
    );
    let pc = Arc::new(PrefixCache::new(8));
    let mixed_handle = PrefixHandle::new(Arc::clone(&pc), "bench-mixed");
    // warm the prefix cache so the mid-flight admissions hit
    decode_scheduled(
        &mixed_model,
        &mixed_cfg,
        &mixed_cache,
        Some(mixed_handle.clone()),
        &mixed_prompts[..2],
        &[0, 0],
    );
    let (cached_mixed, mixed_stats, cached_steps) = decode_scheduled(
        &mixed_model,
        &mixed_cfg,
        &mixed_cache,
        Some(mixed_handle.clone()),
        &mixed_prompts,
        &admit_at,
    );
    assert_eq!(cached_steps, mixed_steps, "mixed board-step count diverged");
    assert_identical(&base_mixed, &cached_mixed, "mixed board");
    assert!(
        mixed_stats.prefix_rows_spliced >= 2,
        "mid-flight hits must be spliced into the windowed forward \
         (got {} spliced rows)",
        mixed_stats.prefix_rows_spliced
    );
    let (t_mixed_off, _) = time_it(
        || {
            std::hint::black_box(decode_scheduled(
                &mixed_model,
                &mixed_cfg,
                &off,
                None,
                &mixed_prompts,
                &admit_at,
            ));
        },
        1,
        iters,
    );
    let (t_mixed_on, _) = time_it(
        || {
            std::hint::black_box(decode_scheduled(
                &mixed_model,
                &mixed_cfg,
                &mixed_cache,
                Some(mixed_handle.clone()),
                &mixed_prompts,
                &admit_at,
            ));
        },
        1,
        iters,
    );
    let mixed_speedup = t_mixed_off / t_mixed_on;
    let mut mixed_table = Table::new(
        "Mixed board: 2 cold + 2 mid-flight prefix hits (dapd-staged, refresh=4)",
        &["mode", "ms/decode", "steps/s", "speedup", "spliced rows"],
    );
    mixed_table.row(vec![
        "uncached".into(),
        fmt_f(t_mixed_off * 1e3, 2),
        fmt_f(mixed_steps as f64 / t_mixed_off, 0),
        "1.00".into(),
        "0".into(),
    ]);
    mixed_table.row(vec![
        "cached+prefix".into(),
        fmt_f(t_mixed_on * 1e3, 2),
        fmt_f(mixed_steps as f64 / t_mixed_on, 0),
        fmt_f(mixed_speedup, 2),
        mixed_stats.prefix_rows_spliced.to_string(),
    ]);
    mixed_table.print();

    // ---- acceptance: >= 1.5x steps/s at refresh_every >= 4, and on ----
    // ---- the mixed-board schedule ------------------------------------
    let min_required: f64 = std::env::var("DAPD_MIN_SPEEDUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);
    println!(
        "\nminimum speedup across methods at refresh_every=4: {:.2}x, \
         mixed-board: {:.2}x (gate: {:.2}x)",
        min_speedup_at_4, mixed_speedup, min_required
    );
    assert!(
        min_speedup_at_4 >= min_required,
        "cache must deliver >= {min_required:.2}x steps/s at refresh_every=4 \
         (got {min_speedup_at_4:.2}x)"
    );
    assert!(
        mixed_speedup >= min_required,
        "mixed boards must deliver >= {min_required:.2}x steps/s \
         (got {mixed_speedup:.2}x)"
    );

    if let Ok(path) = std::env::var("DAPD_BENCH_JSON") {
        let mut out = Json::obj();
        out.set("bench", "cache_reuse".into());
        out.set("min_speedup_at_refresh_4", min_speedup_at_4.into());
        out.set("prefix_first_steps_served", (served as i64).into());
        out.set("mixed_speedup", mixed_speedup.into());
        out.set(
            "mixed_prefix_rows_spliced",
            (mixed_stats.prefix_rows_spliced as i64).into(),
        );
        out.set("mixed_steps", (mixed_steps as i64).into());
        out.set("rows", Json::Arr(rows));
        match std::fs::write(&path, out.dump()) {
            Ok(()) => println!("wrote JSON summary to {path}"),
            Err(e) => eprintln!("failed writing {path}: {e}"),
        }
    }
}
