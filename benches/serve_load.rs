//! Serving-load bench with a persistent, checked-in baseline.
//!
//! Drives the full in-process serving stack (coordinator pool + cached
//! continuous batcher, mock model) with a bursty open-loop workload and
//! reduces the run to a small normalized summary: throughput, request
//! latency percentiles, compute-reuse ratios, and per-kernel hot-loop
//! costs.  A second, heterogeneous workload (zipfian mix of
//! shape-compatible configs) runs twice — per-group sharded and with
//! cross-group stealing — and the bench *requires* stealing to improve
//! board occupancy (plus throughput or queue-wait p95) while every
//! request stays token-identical to its solo per-group reference.  The
//! summary is compared against the checked-in baseline (`BENCH_8.json`
//! at the repo root) with a direction-aware noise band, so CI fails on
//! real regressions rather than on shared-runner jitter.
//!
//! Environment knobs (CI's bench-smoke job sets the first two):
//!   DAPD_BENCH_BASELINE=f  baseline path (default BENCH_8.json)
//!   DAPD_BENCH_NOISE=x     relative tolerance band (default 0.5 = 50%)
//!   DAPD_BENCH_WRITE=1     regenerate the baseline from this run and exit
//!   DAPD_BENCH_JSON=f      also write this run's summary to `f` (artifact)
//!   DAPD_SERVE_N=n         requests to drive (default 48)
//!   DAPD_TRACE_OVERHEAD_MAX=x  allowed steps/s cost of tracing relative
//!                          to the untraced run (default 0.05; CI widens
//!                          it like the noise band — shared runners)

use std::time::{Duration, Instant};

use dapd::cache::CacheConfig;
use dapd::coordinator::{Coordinator, PoolOptions};
use dapd::decode::{decode_batch, DecodeConfig, Method};
use dapd::obs::Stage;
use dapd::runtime::{MockModel, ModelPool};
use dapd::tensor::kernels::{self, Backend};
use dapd::util::bench::{fmt_f, time_it, Table};
use dapd::util::json::Json;
use dapd::util::rng::Pcg;
use dapd::workload::arrivals::{Arrival, ZipfMix};

/// One measured run, already reduced to the baseline schema.
struct Measured {
    steps_per_s: f64,
    tokens_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    prefix_hit_ratio: f64,
    compute_frac: f64,
    /// (op name, mean cost per call in microseconds)
    kernels: Vec<(String, f64)>,
}

impl Measured {
    fn to_json(&self) -> Json {
        let mut tput = Json::obj();
        tput.set("steps_per_s", self.steps_per_s.into());
        tput.set("tokens_per_s", self.tokens_per_s.into());
        let mut lat = Json::obj();
        lat.set("p50", self.p50_ms.into());
        lat.set("p95", self.p95_ms.into());
        lat.set("p99", self.p99_ms.into());
        let mut cache = Json::obj();
        cache.set("prefix_hit_ratio", self.prefix_hit_ratio.into());
        cache.set("compute_frac", self.compute_frac.into());
        let rows = self
            .kernels
            .iter()
            .map(|(op, us)| {
                let mut r = Json::obj();
                r.set("op", op.as_str().into());
                r.set("per_call_us", (*us).into());
                r
            })
            .collect();
        let mut out = Json::obj();
        out.set("bench", "serve_load".into());
        out.set("schema", 1i64.into());
        out.set("throughput", tput);
        out.set("latency_ms", lat);
        out.set("cache", cache);
        out.set("kernels", Json::Arr(rows));
        out
    }
}

/// Drive the bursty workload through a cached 2-worker pool.
fn run_load(n: usize, trace: bool) -> Measured {
    let pool = ModelPool::mock(MockModel::new(4, 68, 28, 92));
    let opts = PoolOptions {
        workers: 2,
        batch_wait: Duration::from_millis(2),
        queue_cap: n + 8,
        cache: CacheConfig {
            enabled: true,
            ..CacheConfig::default()
        },
        trace,
        ..PoolOptions::default()
    };
    let (coord, handles) = Coordinator::start_pool(&pool, &opts).unwrap();

    // a small set of distinct prompts, cycled, so the prefix cache sees
    // repeats (the hit-ratio the baseline tracks)
    let mut rng = Pcg::new(61);
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|_| (0..28).map(|_| (2 + rng.below(90)) as i32).collect())
        .collect();
    let times = Arrival::Bursty {
        burst: 8,
        period: 0.005,
    }
    .schedule(n, &mut rng);

    let cfg = DecodeConfig::new(Method::DapdStaged);
    let t0 = Instant::now();
    let rxs: Vec<_> = times
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            let elapsed = t0.elapsed().as_secs_f64();
            if at > elapsed {
                std::thread::sleep(Duration::from_secs_f64(at - elapsed));
            }
            coord
                .submit(prompts[i % prompts.len()].clone(), cfg.clone())
                .unwrap()
        })
        .collect();
    let mut tokens = 0usize;
    for rx in rxs {
        tokens += rx.recv().unwrap().unwrap().gen.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    handles.join();

    let (p50, p95, p99) = coord.metrics.latency_percentiles();
    // ordering: Relaxed — post-shutdown counter read; all workers have
    // joined, so every increment already happened-before this load.
    let steps = coord.metrics.steps_run.load(std::sync::atomic::Ordering::Relaxed);
    let hit_ratio = coord
        .prefix_cache()
        .map(|pc| pc.hit_rate())
        .unwrap_or(0.0);

    Measured {
        steps_per_s: steps as f64 / wall,
        tokens_per_s: tokens as f64 / wall,
        p50_ms: p50 * 1e3,
        p95_ms: p95 * 1e3,
        p99_ms: p99 * 1e3,
        prefix_hit_ratio: hit_ratio,
        compute_frac: coord.metrics.cache_compute_frac(),
        kernels: kernel_rows(),
    }
}

/// One heterogeneous run, reduced to the scheduler-facing metrics.
struct QueueMeasured {
    steps_per_s: f64,
    tokens_per_s: f64,
    /// mean decoding rows per board step (`slot_steps / steps_run`)
    occupancy: f64,
    wait_p95_ms: f64,
    steals: u64,
    preemptions: u64,
}

/// Drive a zipfian mix of shape-compatible configs (same blocks,
/// different method => different group key, same compat key) through a
/// 2-worker pool, with cross-group stealing on or off.  Every response
/// is checked token-identical against a solo per-group reference decode
/// before any numbers are reported.
fn run_hetero(n: usize, steal: bool) -> QueueMeasured {
    let pool = ModelPool::mock(MockModel::new(4, 68, 28, 92));
    let opts = PoolOptions {
        workers: 2,
        batch_wait: Duration::from_millis(2),
        queue_cap: n + 8,
        steal,
        ..PoolOptions::default()
    };
    let (coord, handles) = Coordinator::start_pool(&pool, &opts).unwrap();

    let mut rng = Pcg::new(83);
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|_| (0..28).map(|_| (2 + rng.below(90)) as i32).collect())
        .collect();
    // head-heavy method mix: the tail groups cannot fill a board alone,
    // which is exactly where per-group sharding strands capacity
    let methods = [
        Method::DapdStaged,
        Method::FastDllm,
        Method::EbSampler,
        Method::Klass,
        Method::DapdDirect,
        Method::Original,
    ];
    let cfgs: Vec<DecodeConfig> = methods.iter().map(|&m| DecodeConfig::new(m)).collect();
    let groups = ZipfMix::new(cfgs.len(), 1.2).assign(n, &mut rng);

    // closed burst: everything queued up front, so scheduling (not
    // arrival pacing) decides how full the boards run
    let t0 = Instant::now();
    let rxs: Vec<_> = groups
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            coord
                .submit(prompts[i % prompts.len()].clone(), cfgs[g].clone())
                .unwrap()
        })
        .collect();
    let mut tokens = 0usize;
    let mut gens: Vec<Vec<i32>> = Vec::with_capacity(n);
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        tokens += r.gen.len();
        gens.push(r.gen);
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    handles.join();

    // token identity: mixed-config packing must not change any output
    let refmodel = MockModel::new(4, 68, 28, 92);
    for (i, &g) in groups.iter().enumerate() {
        let reference = decode_batch(
            &refmodel,
            std::slice::from_ref(&prompts[i % prompts.len()]),
            &cfgs[g],
        )
        .unwrap();
        assert_eq!(
            gens[i], reference[0].gen,
            "request {i} (group {g}, steal={steal}) diverged from its solo reference"
        );
    }

    // ordering: Relaxed — post-shutdown counter read; see above.
    let steps = coord.metrics.steps_run.load(std::sync::atomic::Ordering::Relaxed);
    QueueMeasured {
        steps_per_s: steps as f64 / wall,
        tokens_per_s: tokens as f64 / wall,
        occupancy: coord.metrics.mean_batch_size(),
        wait_p95_ms: coord
            .metrics
            .stage_hists()
            .get(Stage::QueueWait)
            .quantile(0.95)
            * 1e3,
        steals: coord
            .metrics
            .steals
            // ordering: Relaxed — post-shutdown counter read; see above.
            .load(std::sync::atomic::Ordering::Relaxed),
        preemptions: coord
            .metrics
            .preemptions
            // ordering: Relaxed — post-shutdown counter read; see above.
            .load(std::sync::atomic::Ordering::Relaxed),
    }
}

/// Per-kernel costs of the vocab-width hot loops on the dispatched
/// (native-when-available) backend, in microseconds per call.
fn kernel_rows() -> Vec<(String, f64)> {
    let mut rng = Pcg::new(7);
    let kv = 256usize;
    let rows: Vec<Vec<f32>> = (0..40)
        .map(|_| {
            let mut r: Vec<f32> = (0..kv).map(|_| rng.f64() as f32 * 8.0).collect();
            kernels::softmax_inplace(Backend::Scalar, &mut r);
            r
        })
        .collect();
    let mut buf = vec![0.0f32; kv];
    let calls = rows.len() as f64;
    let mut out = Vec::new();

    let (m, _) = time_it(
        || {
            for (r, q) in rows.iter().zip(rows.iter().rev()) {
                buf.copy_from_slice(r);
                std::hint::black_box(kernels::softmax_stats(
                    Backend::Native,
                    &mut buf,
                    Some(q.as_slice()),
                ));
            }
        },
        20,
        200,
    );
    out.push(("softmax_stats".to_string(), m / calls * 1e6));
    let (m, _) = time_it(
        || {
            for q in &rows {
                std::hint::black_box(kernels::argmax(Backend::Native, q));
            }
        },
        20,
        200,
    );
    out.push(("argmax".to_string(), m / calls * 1e6));
    let (m, _) = time_it(
        || {
            for q in &rows {
                std::hint::black_box(kernels::entropy(Backend::Native, q));
            }
        },
        20,
        200,
    );
    out.push(("entropy".to_string(), m / calls * 1e6));
    let (m, _) = time_it(
        || {
            for (r, q) in rows.iter().zip(rows.iter().rev()) {
                std::hint::black_box(kernels::kl_div(Backend::Native, r, q));
            }
        },
        20,
        200,
    );
    out.push(("kl_div".to_string(), m / calls * 1e6));
    out
}

/// Direction-aware baseline comparison within a relative noise band.
struct Gate {
    noise: f64,
    checked: usize,
    regressions: Vec<String>,
}

impl Gate {
    fn check(&mut self, name: &str, cur: f64, base: Option<f64>, higher_is_better: bool) {
        let Some(b) = base else {
            println!("  (no baseline entry for {name}; skipped)");
            return;
        };
        if b <= 0.0 || !b.is_finite() {
            println!("  (baseline {name}={b} is not gateable; skipped)");
            return;
        }
        self.checked += 1;
        let (ok, bound) = if higher_is_better {
            (cur >= b * (1.0 - self.noise), b * (1.0 - self.noise))
        } else {
            (cur <= b * (1.0 + self.noise), b * (1.0 + self.noise))
        };
        if !ok {
            self.regressions.push(format!(
                "{name}: {cur:.3} vs baseline {b:.3} (allowed {} {bound:.3})",
                if higher_is_better { ">=" } else { "<=" }
            ));
        }
    }
}

fn baseline_kernel_us(base: &Json, op: &str) -> Option<f64> {
    base.get("kernels").as_arr()?.iter().find_map(|r| {
        if r.get("op").as_str() == Some(op) {
            r.get("per_call_us").as_f64()
        } else {
            None
        }
    })
}

fn main() {
    let n: usize = std::env::var("DAPD_SERVE_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    let baseline_path =
        std::env::var("DAPD_BENCH_BASELINE").unwrap_or_else(|_| "BENCH_8.json".to_string());
    let noise: f64 = std::env::var("DAPD_BENCH_NOISE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);

    let m = run_load(n, false);
    // same workload with decode-path tracing on: the overhead of the
    // ring-buffer recording relative to the untraced run
    let traced = run_load(n, true);
    let trace_overhead = 1.0 - traced.steps_per_s / m.steps_per_s;
    // heterogeneous mix, sharded vs cross-group stealing (same seed:
    // identical prompts, configs, and assignment in both runs)
    let sharded = run_hetero(n, false);
    let stolen = run_hetero(n, true);

    let mut t = Table::new(
        &format!("Serving load summary (bursty open loop, n={n}, 2 workers)"),
        &["metric", "value"],
    );
    t.row(vec!["steps/s".into(), fmt_f(m.steps_per_s, 1)]);
    t.row(vec!["tokens/s".into(), fmt_f(m.tokens_per_s, 1)]);
    t.row(vec!["latency p50 (ms)".into(), fmt_f(m.p50_ms, 2)]);
    t.row(vec!["latency p95 (ms)".into(), fmt_f(m.p95_ms, 2)]);
    t.row(vec!["latency p99 (ms)".into(), fmt_f(m.p99_ms, 2)]);
    t.row(vec!["prefix hit ratio".into(), fmt_f(m.prefix_hit_ratio, 3)]);
    t.row(vec!["compute frac".into(), fmt_f(m.compute_frac, 3)]);
    for (op, us) in &m.kernels {
        t.row(vec![format!("kernel {op} (us/call)"), fmt_f(*us, 3)]);
    }
    t.row(vec!["steps/s (traced)".into(), fmt_f(traced.steps_per_s, 1)]);
    t.row(vec![
        "trace overhead".into(),
        format!("{:.1}%", trace_overhead * 100.0),
    ]);
    t.print();

    let mut h = Table::new(
        &format!("Heterogeneous mix (zipf over 6 configs, n={n}, 2 workers)"),
        &["metric", "sharded", "stealing"],
    );
    h.row(vec![
        "board occupancy".into(),
        fmt_f(sharded.occupancy, 3),
        fmt_f(stolen.occupancy, 3),
    ]);
    h.row(vec![
        "queue wait p95 (ms)".into(),
        fmt_f(sharded.wait_p95_ms, 2),
        fmt_f(stolen.wait_p95_ms, 2),
    ]);
    h.row(vec![
        "steps/s".into(),
        fmt_f(sharded.steps_per_s, 1),
        fmt_f(stolen.steps_per_s, 1),
    ]);
    h.row(vec![
        "tokens/s".into(),
        fmt_f(sharded.tokens_per_s, 1),
        fmt_f(stolen.tokens_per_s, 1),
    ]);
    h.row(vec![
        "steals".into(),
        sharded.steals.to_string(),
        stolen.steals.to_string(),
    ]);
    h.row(vec![
        "preemptions".into(),
        sharded.preemptions.to_string(),
        stolen.preemptions.to_string(),
    ]);
    h.print();

    // the point of cross-group packing: boards run fuller, and that
    // shows up as throughput or shorter queues — in the same run
    assert_eq!(sharded.steals, 0, "stealing disabled must never steal");
    assert!(stolen.steals > 0, "heterogeneous mix must exercise stealing");
    assert!(
        stolen.occupancy > sharded.occupancy * 1.02,
        "cross-group packing must improve board occupancy: {} vs {} sharded",
        stolen.occupancy,
        sharded.occupancy
    );
    assert!(
        stolen.steps_per_s > sharded.steps_per_s
            || stolen.tokens_per_s > sharded.tokens_per_s
            || stolen.wait_p95_ms < sharded.wait_p95_ms,
        "stealing improved neither throughput ({} vs {} steps/s, {} vs {} tok/s) \
         nor queue-wait p95 ({} vs {} ms)",
        stolen.steps_per_s,
        sharded.steps_per_s,
        stolen.tokens_per_s,
        sharded.tokens_per_s,
        stolen.wait_p95_ms,
        sharded.wait_p95_ms
    );

    let mut summary = m.to_json();
    let mut obs = Json::obj();
    obs.set("steps_per_s_traced", traced.steps_per_s.into());
    obs.set("trace_overhead_frac", trace_overhead.into());
    summary.set("obs", obs);
    let mut queue = Json::obj();
    queue.set("wait_p95_ms", stolen.wait_p95_ms.into());
    queue.set("occupancy", stolen.occupancy.into());
    queue.set("occupancy_sharded", sharded.occupancy.into());
    queue.set("steals", (stolen.steals as i64).into());
    queue.set("preemptions", (stolen.preemptions as i64).into());
    summary.set("queue", queue);
    if let Ok(path) = std::env::var("DAPD_BENCH_JSON") {
        match std::fs::write(&path, summary.dump_pretty()) {
            Ok(()) => println!("wrote JSON summary to {path}"),
            Err(e) => eprintln!("failed writing {path}: {e}"),
        }
    }

    if std::env::var("DAPD_BENCH_WRITE").is_ok() {
        std::fs::write(&baseline_path, summary.dump_pretty())
            .unwrap_or_else(|e| panic!("failed writing baseline {baseline_path}: {e}"));
        println!("regenerated baseline {baseline_path} from this run");
        return;
    }

    let text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(e) => {
            // a missing baseline is a hard failure in CI: the gate exists
            // to catch regressions, and silently skipping it would read
            // as a pass
            panic!(
                "baseline {baseline_path} unreadable ({e}); regenerate with \
                 DAPD_BENCH_WRITE=1 or point DAPD_BENCH_BASELINE elsewhere"
            );
        }
    };
    let base = Json::parse(&text)
        .unwrap_or_else(|e| panic!("baseline {baseline_path} is not valid JSON: {e}"));

    println!("\ncomparing against {baseline_path} (noise band {:.0}%)", noise * 100.0);
    let mut gate = Gate {
        noise,
        checked: 0,
        regressions: Vec::new(),
    };
    let tput = base.get("throughput");
    gate.check(
        "throughput.steps_per_s",
        m.steps_per_s,
        tput.get("steps_per_s").as_f64(),
        true,
    );
    gate.check(
        "throughput.tokens_per_s",
        m.tokens_per_s,
        tput.get("tokens_per_s").as_f64(),
        true,
    );
    let lat = base.get("latency_ms");
    gate.check("latency_ms.p50", m.p50_ms, lat.get("p50").as_f64(), false);
    gate.check("latency_ms.p95", m.p95_ms, lat.get("p95").as_f64(), false);
    gate.check("latency_ms.p99", m.p99_ms, lat.get("p99").as_f64(), false);
    let cache = base.get("cache");
    gate.check(
        "cache.prefix_hit_ratio",
        m.prefix_hit_ratio,
        cache.get("prefix_hit_ratio").as_f64(),
        true,
    );
    gate.check(
        "cache.compute_frac",
        m.compute_frac,
        cache.get("compute_frac").as_f64(),
        false,
    );
    for (op, us) in &m.kernels {
        gate.check(
            &format!("kernels.{op}.per_call_us"),
            *us,
            baseline_kernel_us(&base, op),
            false,
        );
    }
    let q = base.get("queue");
    gate.check(
        "queue.wait_p95_ms",
        stolen.wait_p95_ms,
        q.get("wait_p95_ms").as_f64(),
        false,
    );
    gate.check(
        "queue.occupancy",
        stolen.occupancy,
        q.get("occupancy").as_f64(),
        true,
    );
    // steals/preemptions are recorded in the baseline for trend
    // visibility; zero baselines are not gateable and skip cleanly
    gate.check(
        "queue.steals",
        stolen.steals as f64,
        q.get("steals").as_f64(),
        true,
    );
    gate.check(
        "queue.preemptions",
        stolen.preemptions as f64,
        q.get("preemptions").as_f64(),
        true,
    );

    // tracing must stay close to free even when enabled (the disabled
    // path is gated by the zero-alloc test; this bounds the enabled one)
    let max_overhead: f64 = std::env::var("DAPD_TRACE_OVERHEAD_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.05);
    assert!(
        trace_overhead <= max_overhead,
        "tracing cost {:.1}% of steps/s (allowed {:.1}%; widen via \
         DAPD_TRACE_OVERHEAD_MAX on noisy runners)",
        trace_overhead * 100.0,
        max_overhead * 100.0
    );

    assert!(gate.checked > 0, "baseline {baseline_path} gated nothing");
    if gate.regressions.is_empty() {
        println!(
            "baseline gate passed: {} metric(s) within the {:.0}% band",
            gate.checked,
            noise * 100.0
        );
    } else {
        for r in &gate.regressions {
            eprintln!("REGRESSION {r}");
        }
        panic!(
            "{} of {} gated metric(s) regressed beyond the {:.0}% noise band \
             (widen via DAPD_BENCH_NOISE or regenerate via DAPD_BENCH_WRITE=1 \
             if the change is intentional)",
            gate.regressions.len(),
            gate.checked,
            noise * 100.0
        );
    }
}
