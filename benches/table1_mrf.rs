//! Tables 1, 9, 10: MRF validation — attention as a dependency signal.
//!
//! Table 1: overall AUC / edge-to-non-edge ratio / OVR (last-2 layers).
//! Table 9: the same metrics per decoding step (mean ± sd over paths).
//! Table 10: layer-selection ablation.
//!
//! Paper reference: AUC 0.928, ratio 2.204, OVR 0.04 (30 models x 100
//! paths on 8-layer RADD toys); this testbed trains 3 seeds.

mod common;

use dapd::eval::mrf::{run_mrf_validation, LayerSel, MrfSummary};
use dapd::runtime::ArtifactKind;
use dapd::util::bench::{fmt_f, Table};
use dapd::util::stats;

fn main() {
    let engine = common::engine();
    let paths = common::n_samples(50);
    let toys: Vec<_> = engine
        .meta
        .artifacts
        .iter()
        .filter(|a| a.kind == ArtifactKind::Toy && a.batch > 1)
        .cloned()
        .collect();
    assert!(!toys.is_empty(), "no toy artifacts");

    // ---- Table 1 -----------------------------------------------------
    let mut summaries: Vec<MrfSummary> = Vec::new();
    for a in &toys {
        let model = engine.model(&a.name).unwrap();
        summaries.push(
            run_mrf_validation(&model, &engine.meta.mrf, a.n_layers, LayerSel::LastK(2), paths, 7)
                .unwrap(),
        );
    }
    let mut t1 = Table::new(
        &format!("Table 1: edge detection & degree estimation ({} models x {paths} paths, last-2 layers)",
                 toys.len()),
        &["", "AUC", "Ratio (Edge/Non-edge)", "OVR"],
    );
    let aucs: Vec<f64> = summaries.iter().map(|s| s.auc).collect();
    let ratios: Vec<f64> = summaries.iter().map(|s| s.ratio).collect();
    let ovrs: Vec<f64> = summaries.iter().map(|s| s.ovr).collect();
    t1.row(vec![
        "measured".into(),
        fmt_f(stats::mean(&aucs), 3),
        fmt_f(stats::mean(&ratios), 3),
        fmt_f(stats::mean(&ovrs), 3),
    ]);
    t1.row(vec!["paper".into(), "0.928".into(), "2.204".into(), "0.04".into()]);
    t1.print();

    // ---- Table 9: per-step -------------------------------------------
    let mut t9 = Table::new(
        "Table 9: metrics across decoding steps (mean +/- sd, model 0)",
        &["Step", "AUC", "Ratio", "OVR"],
    );
    for sm in &summaries[0].per_step {
        t9.row(vec![
            sm.step.to_string(),
            format!("{:.3} +/- {:.2}", sm.auc_mean, sm.auc_sd),
            format!("{:.2} +/- {:.2}", sm.ratio_mean, sm.ratio_sd),
            format!("{:.2} +/- {:.2}", sm.ovr_mean, sm.ovr_sd),
        ]);
    }
    t9.print();

    // ---- Table 10: layer ablation ------------------------------------
    let sels = [
        LayerSel::LastK(2),
        LayerSel::LastK(1),
        LayerSel::LastK(4),
        LayerSel::All,
        LayerSel::FirstK(4),
        LayerSel::FirstK(2),
        LayerSel::FirstK(1),
    ];
    let mut t10 = Table::new(
        "Table 10: layer-selection ablation (paper: last-2 best, first-1 worst)",
        &["Layer Selection", "AUC", "Ratio", "OVR"],
    );
    for sel in sels {
        let mut aucs = Vec::new();
        let mut ratios = Vec::new();
        let mut ovrs = Vec::new();
        for a in &toys {
            let model = engine.model(&a.name).unwrap();
            let s =
                run_mrf_validation(&model, &engine.meta.mrf, a.n_layers, sel, paths, 7).unwrap();
            aucs.push(s.auc);
            ratios.push(s.ratio);
            ovrs.push(s.ovr);
        }
        t10.row(vec![
            sel.label(),
            fmt_f(stats::mean(&aucs), 3),
            fmt_f(stats::mean(&ratios), 3),
            fmt_f(stats::mean(&ovrs), 3),
        ]);
    }
    t10.print();
}
