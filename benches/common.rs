//! Shared helpers for the custom bench harness (criterion is not
//! vendored offline; see Cargo.toml `harness = false` targets).
//!
//! Environment knobs:
//!   DAPD_N=60         samples per task (default varies per bench)
//!   DAPD_ARTIFACTS=…  artifact directory (default ./artifacts)
#![allow(dead_code)]

use dapd::decode::{DecodeConfig, Method, MethodParams};
use dapd::runtime::Engine;

pub fn engine() -> Engine {
    let dir = std::env::var("DAPD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Engine::load(std::path::Path::new(&dir))
        .expect("artifacts not found - run `make artifacts` first")
}

pub fn n_samples(default: usize) -> usize {
    std::env::var("DAPD_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The paper's method lineup for the main tables.
pub fn baseline_methods() -> Vec<Method> {
    vec![Method::FastDllm, Method::EbSampler, Method::Klass]
}

pub fn dapd_methods() -> Vec<Method> {
    vec![Method::DapdStaged, Method::DapdDirect]
}

/// Default config matching the paper's App. A hyperparameters.
pub fn cfg(method: Method) -> DecodeConfig {
    let mut c = DecodeConfig::new(method);
    c.params = MethodParams::default();
    c
}

pub fn pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}
