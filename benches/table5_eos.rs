//! Table 5: EOS overflow — why baselines need block decoding or EOS
//! suppression on LLaDA-style models.
//!
//! Paper shape: single-block baselines collapse (e.g. Fast-dLLM GSM8K
//! 7.5%), EOS-Inf restores accuracy at much higher step counts, 4-block
//! recovers accuracy at moderate steps.  sim-llada was trained with
//! EOS-filled targets precisely to reproduce this failure mode.

mod common;

use dapd::eval::run_eval;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(40);
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len).unwrap();
    let tasks = ["struct", "arith", "multiq"];

    let mut t = Table::new(
        &format!("Table 5: decoding-setting ablation on sim-llada (n={n}/task)"),
        &["Method", "Setting", "Task", "Acc.", "Steps"],
    );
    for method in common::baseline_methods() {
        for (setting, blocks, eos_inf) in
            [("1 block", 1usize, false), ("1 block + EOS-Inf", 1, true), ("4 blocks", 4, false)]
        {
            for task in tasks {
                let set = EvalSet::load(&engine.meta, task).unwrap().take(n);
                let mut cfg = common::cfg(method);
                cfg.blocks = blocks;
                cfg.eos_suppress = eos_inf;
                cfg.eos_id = engine.meta.special.eos;
                let r = run_eval(&model, &set, &cfg, method.name()).unwrap();
                t.row(vec![
                    method.name().into(),
                    setting.into(),
                    task.into(),
                    fmt_f(r.accuracy_pct(), 1),
                    fmt_f(r.avg_steps, 1),
                ]);
            }
        }
    }
    t.print();
    println!(
        "paper shape: 1-block << EOS-Inf ~ 4-block accuracy; EOS-Inf needs \
         the most steps (DAPD itself stays single-block, Table 3)"
    );
}
