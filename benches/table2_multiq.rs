//! Table 2 + Fig. 5: the Sec. 6 bundled-questions analysis.
//!
//! Paper reference (LLaDA, TriviaQA x5, 256 tokens):
//!   Original 52.64 / 256.0 (1.00x);  Fast-dLLM 52.12 / 124.4 (2.06x);
//!   KLASS 52.2 / 177.4 (1.44x);  EB 51.2 / 131.3 (1.95x);
//!   DAPD 52.08 / 66.2 (3.87x)  — plus segment-count divergence (Fig. 5).

mod common;

use dapd::decode::Method;
use dapd::eval::{run_eval, segments};
use dapd::runtime::ForwardModel;
use dapd::util::bench::{fmt_f, Table};
use dapd::workload::EvalSet;

fn main() {
    let engine = common::engine();
    let n = common::n_samples(60);
    let model = engine.model_for("sim-llada", 8, engine.meta.gen_len).unwrap();
    let set = EvalSet::load(&engine.meta, "multiq").unwrap().take(n);
    let gen_len = model.gen_len();

    let methods = [
        Method::Original,
        Method::FastDllm,
        Method::Klass,
        Method::EbSampler,
        Method::DapdStaged,
    ];
    let mut t = Table::new(
        &format!("Table 2: multiq accuracy / steps / speedup (n={n})"),
        &["Method", "Acc.", "Steps", "Speedup", "PeakSegs"],
    );
    let mut base = 0.0;
    let mut curves = Vec::new();
    for method in methods {
        let r = run_eval(&model, &set, &common::cfg(method), method.name()).unwrap();
        if method == Method::Original {
            base = r.avg_steps;
        }
        t.row(vec![
            method.name().into(),
            fmt_f(r.accuracy_pct(), 2),
            fmt_f(r.avg_steps, 1),
            format!("{:.2}x", base / r.avg_steps.max(1e-9)),
            fmt_f(segments::peak_segments(&r.outcomes, gen_len), 2),
        ]);
        curves.push((
            method.name(),
            segments::mean_segment_curve(&r.outcomes, gen_len, 10),
        ));
    }
    t.print();
    println!("paper: DAPD 3.87x vs best baseline 2.06x at matched accuracy");

    println!("\nFig. 5 (right) analogue: mean segment count at normalized progress");
    for (name, curve) in curves {
        println!(
            "  {name:<12} {}",
            curve.iter().map(|c| format!("{c:4.1}")).collect::<Vec<_>>().join(" ")
        );
    }
    println!("  (DAPD should rise then merge; baselines stay near 1-2)");
}
