"""AOT pipeline: train (or load cached) weights, lower to HLO text, and
export everything the Rust coordinator needs.

Run once at build time (``make artifacts``); Python never appears on the
request path.  Outputs, all under ``artifacts/``:

  params/{model}.npz          cached trained weights (keyed by config hash)
  {model}_b{B}_L{L}.hlo.txt   AOT-lowered forward passes, weights baked in
  eval/{task}.json            deterministic eval sets (shared with rust)
  metadata.json               vocab, model configs, artifact registry,
                              world tables, training report

Interchange format is HLO **text** with ``print_large_constants=True``:
jax >= 0.5 emits serialized protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects, and the default printer elides the baked
weight constants (``constant({...})``) which silently zero-initializes
the model on the rust side.  Both gotchas are covered by tests.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import vocab as V
from .model import (ModelConfig, count_params, model_zoo, params_from_flat,
                    params_to_flat, serving_forward, toy_forward)
from .train import train_mrf_toy, train_serving_model

EVAL_TASKS = ["arith", "struct", "constraint", "multiq", "pbench-copy",
              "pbench-rev", "pbench-sort", "pbench-latin", "pbench-para",
              "pbench-w2s"]
EVAL_N = {"multiq": 100}
EVAL_N_DEFAULT = 120

# Serving artifact grid: (batch sizes, gen lengths).  gen < GEN_LEN slices
# the positional table (Table 7 length sweep).
SERVING_BATCHES = [1, 2, 4, 8]
TOY_BATCHES = [1, 16]
TABLE7_GENS = [16, 28, 40]

# Calibrated for the 1-core CPU testbed; sim-llada needs the extra steps
# to learn prompt-copying through the EOS-heavy targets.
TRAIN_STEPS = {"sim-llada": 2600, "sim-dream": 2000, "mrf-toy": 3000}
# 2 seeds (paper: 30) — each toy needs 5k steps on the 1-core testbed
TOY_SEEDS = [0, 1]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (constants included)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def config_hash(cfg: ModelConfig, steps: int, seed: int) -> str:
    cfg_dict = dict(cfg.__dict__)
    # default-valued late additions are dropped so pre-existing param
    # caches stay valid when a new knob is introduced
    if cfg_dict.get("attn_init_scale") == 0.02:
        cfg_dict.pop("attn_init_scale")
    blob = {"cfg": cfg_dict, "steps": steps, "seed": seed}
    # the serving corpus fingerprint is irrelevant to the MRF toy, whose
    # dataset is fixed by construction
    if cfg.name != "mrf-toy":
        blob["world"] = _WORLD_FINGERPRINT
    return hashlib.sha256(
        json.dumps(blob, sort_keys=True).encode()).hexdigest()[:16]


_WORLD_FINGERPRINT = {"fact": D.fact_table(), "para": D.para_table(),
                      "prompt_len": D.PROMPT_LEN, "gen_len": D.GEN_LEN,
                      # v2: template-variant corpus (marginally ambiguous,
                      # jointly constrained outputs — see datasets.py)
                      "corpus_version": 2}


# ---------------------------------------------------------------------------
# Param cache
# ---------------------------------------------------------------------------

def train_or_load(cfg: ModelConfig, art_dir: str, *, steps: int, seed: int,
                  eos_fill: bool, force: bool):
    os.makedirs(os.path.join(art_dir, "params"), exist_ok=True)
    tag = f"{cfg.name}-s{seed}" if cfg.name == "mrf-toy" else cfg.name
    path = os.path.join(art_dir, "params", f"{tag}.npz")
    want = config_hash(cfg, steps, seed)
    if not force and os.path.exists(path):
        data = np.load(path, allow_pickle=False)
        if str(data["__hash__"]) == want:
            print(f"[aot] cache hit: {tag}")
            return params_from_flat(
                {k: v for k, v in data.items() if k != "__hash__"}, cfg), []
        print(f"[aot] cache stale: {tag} (retraining)")
    t0 = time.time()
    if cfg.name == "mrf-toy":
        params, hist = train_mrf_toy(cfg, steps=steps, seed=seed)
    else:
        params, hist = train_serving_model(cfg, eos_fill=eos_fill,
                                           steps=steps, seed=seed)
    print(f"[aot] trained {tag} ({count_params(params)} params) "
          f"in {time.time() - t0:.0f}s")
    flat = params_to_flat(params)
    flat["__hash__"] = np.asarray(want)
    np.savez(path, **flat)
    return params, hist


# ---------------------------------------------------------------------------
# Greedy step-by-step probe (training sanity signal, python-side only)
# ---------------------------------------------------------------------------

def greedy_probe(params, cfg: ModelConfig, task: str, n: int = 12,
                 gen_len: int = D.GEN_LEN) -> float:
    """Token-by-token max-confidence decode; exact-match vs expected."""
    samples = D.eval_set(task, n, seed=99)
    fwd = jax.jit(lambda toks: serving_forward(params, cfg, toks,
                                               use_pallas=False))
    correct = 0
    for s in samples:
        toks = np.array(s["prompt"] + [cfg.mask_id] * gen_len, np.int32)
        toks = toks[None]
        for _ in range(gen_len):
            logits = np.asarray(fwd(jnp.asarray(toks))[0])[0]
            probs = _softmax(logits)
            masked = np.where(toks[0] == cfg.mask_id)[0]
            conf = probs[masked].max(axis=-1)
            pos = masked[int(conf.argmax())]
            toks[0, pos] = int(probs[pos].argmax())
        gen = list(toks[0][D.PROMPT_LEN:])
        exp = s["expect"]
        if gen[:len(exp)] == exp:
            correct += 1
    return correct / n


def _softmax(x):
    e = np.exp(x - x.max(axis=-1, keepdims=True))
    return e / e.sum(axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def lower_serving(params, cfg: ModelConfig, batch: int, gen_len: int) -> str:
    seq = D.PROMPT_LEN + gen_len
    spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    fn = lambda toks: serving_forward(params, cfg, toks, use_pallas=True,
                                      seq_len=seq)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_toy(params, cfg: ModelConfig, batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    fn = lambda toks: toy_forward(params, cfg, toks, use_pallas=True)
    return to_hlo_text(jax.jit(fn).lower(spec))


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="sim-llada,sim-dream,mrf-toy",
                    help="comma-separated subset to build")
    ap.add_argument("--steps", type=int, default=0,
                    help="override training steps for all models")
    ap.add_argument("--force", action="store_true",
                    help="retrain even on param-cache hit")
    ap.add_argument("--skip-probe", action="store_true")
    args = ap.parse_args()

    art = args.out_dir
    os.makedirs(art, exist_ok=True)
    os.makedirs(os.path.join(art, "eval"), exist_ok=True)
    zoo = model_zoo()
    wanted = args.models.split(",")
    # Incremental builds: keep registry/report entries of models NOT being
    # rebuilt, so `--models sim-llada` refreshes one model while the rest
    # of artifacts/metadata.json stays valid.
    registry = []
    report = {}
    meta_path = os.path.join(art, "metadata.json")
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            old = json.load(f)
        registry = [a for a in old.get("artifacts", [])
                    if a["model"] not in wanted
                    and not (a["model"].startswith("mrf-toy") and "mrf-toy" in wanted)]
        report = {k: v for k, v in old.get("train_report", {}).items()
                  if k not in wanted
                  and not (k.startswith("mrf-toy") and "mrf-toy" in wanted)}
        if registry:
            print(f"[aot] kept {len(registry)} artifacts from existing metadata")

    for name in wanted:
        cfg = zoo[name]
        steps = args.steps or TRAIN_STEPS[name]
        if name == "mrf-toy":
            for seed in TOY_SEEDS:
                params, hist = train_or_load(cfg, art, steps=steps,
                                             seed=seed, eos_fill=False,
                                             force=args.force)
                report[f"{name}-s{seed}"] = {"loss_hist": hist}
                for b in TOY_BATCHES:
                    fname = f"mrf-toy-s{seed}_b{b}_L{cfg.seq_len}.hlo.txt"
                    text = lower_toy(params, cfg, b)
                    with open(os.path.join(art, fname), "w") as f:
                        f.write(text)
                    registry.append({
                        "name": f"mrf-toy-s{seed}_b{b}",
                        "model": f"mrf-toy-s{seed}", "file": fname,
                        "kind": "toy", "batch": b, "seq_len": cfg.seq_len,
                        "prompt_len": 0, "gen_len": cfg.seq_len,
                        "outputs": ["logits", "attn_layers"],
                        "vocab": cfg.vocab, "mask_id": cfg.mask_id,
                        "pad_id": cfg.pad_id, "n_layers": cfg.n_layers,
                        "n_heads": cfg.n_heads, "d_model": cfg.d_model,
                        "graph_layers": cfg.graph_layers(),
                    })
                    print(f"[aot] wrote {fname} ({len(text)} chars)")
        else:
            eos_fill = name == "sim-llada"
            params, hist = train_or_load(cfg, art, steps=steps, seed=7,
                                         eos_fill=eos_fill, force=args.force)
            rep = {"loss_hist": hist}
            if not args.skip_probe:
                # probe tasks with a unique rendering (template-variant
                # tasks would fail exact-prefix matching spuriously)
                for task in ["constraint", "pbench-para", "arith"]:
                    acc = greedy_probe(params, cfg, task)
                    rep[f"probe_{task}"] = acc
                    print(f"[aot] {name} greedy probe {task}: {acc:.2f}")
            report[name] = rep
            gens = TABLE7_GENS if name == "sim-llada" else [D.GEN_LEN]
            for gen_len in gens:
                batches = SERVING_BATCHES if gen_len == D.GEN_LEN else [1, 4]
                for b in batches:
                    seq = D.PROMPT_LEN + gen_len
                    fname = f"{name}_b{b}_L{seq}.hlo.txt"
                    text = lower_serving(params, cfg, b, gen_len)
                    with open(os.path.join(art, fname), "w") as f:
                        f.write(text)
                    registry.append({
                        "name": f"{name}_b{b}_g{gen_len}",
                        "model": name, "file": fname, "kind": "serving",
                        "batch": b, "seq_len": seq,
                        "prompt_len": D.PROMPT_LEN, "gen_len": gen_len,
                        "outputs": ["logits", "attn_avg", "edge_scores",
                                    "degrees"],
                        "vocab": cfg.vocab, "mask_id": cfg.mask_id,
                        "pad_id": cfg.pad_id, "n_layers": cfg.n_layers,
                        "n_heads": cfg.n_heads, "d_model": cfg.d_model,
                        "graph_layers": cfg.graph_layers(),
                    })
                    print(f"[aot] wrote {fname} ({len(text)} chars)")

    # Eval sets (deterministic; shared with rust/src/workload)
    eval_files = {}
    for task in EVAL_TASKS:
        n = EVAL_N.get(task, EVAL_N_DEFAULT)
        data = D.eval_set(task, n, seed=2026)
        fname = f"eval/{task}.json"
        with open(os.path.join(art, fname), "w") as f:
            json.dump(data, f)
        eval_files[task] = {"file": fname, "n": n}

    meta = {
        "version": 1,
        "vocab_size": V.VOCAB_SIZE,
        "vocab": V.vocab_table(),
        "special": {"pad": V.PAD, "mask": V.MASK, "eos": V.EOS,
                    "sep": V.SEP, "fill": V.FILL},
        "prompt_len": D.PROMPT_LEN,
        "gen_len": D.GEN_LEN,
        "world": {"fact": D.fact_table(), "para": D.para_table()},
        "mrf": {"len": D.MRF_LEN, "vocab": D.MRF_VOCAB,
                "mask_id": D.MRF_MASK_ID,
                "true_edges": D.mrf_true_edges(),
                "true_degrees": D.mrf_true_degrees()},
        "artifacts": registry,
        "eval_sets": eval_files,
        "train_report": report,
    }
    with open(os.path.join(art, "metadata.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] wrote metadata.json ({len(registry)} artifacts)")


if __name__ == "__main__":
    main()
