"""Build-time trainer for the simulated dLLMs (never on the request path).

Implements the LLaDA/MDM objective: sample t ~ U(0,1), mask each
response-region token independently with probability t, and minimize the
1/t-weighted masked cross-entropy

    L = -E_{t, x_t} [ (1/t) * sum_{i: x_t^i = [M]} log p_theta(x_0^i | x_t) ]

Prompt positions are never masked (instruction-tuning convention), so the
model learns conditional marginals for the generation window only —
exactly the quantity DAPD decodes from.

Optimizer is a hand-rolled AdamW (optax is not available in this image);
cosine LR with warmup.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from .model import ModelConfig, forward, init_params


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8,
                 weight_decay=0.01):
    step = state["step"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

    def upd(p, m_, v_):
        update = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        return p - lr * (update + weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


def lr_schedule(step, base_lr, warmup, total):
    warm = base_lr * (step + 1) / warmup
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
    cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# MDM loss
# ---------------------------------------------------------------------------

def mdm_loss(params, cfg: ModelConfig, x0, resp_mask, t, noise):
    """LLaDA masked-diffusion loss for one batch.

    x0: [B, L] clean tokens; resp_mask: [B, L] {0,1} maskable region;
    t: [B] masking rates; noise: [B, L] uniforms for mask sampling.
    """
    masked = (noise < t[:, None]) & (resp_mask > 0)
    xt = jnp.where(masked, cfg.mask_id, x0)
    logits, _ = forward(params, cfg, xt, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_logp = jnp.take_along_axis(logp, x0[..., None], axis=-1)[..., 0]
    weight = masked.astype(jnp.float32) / jnp.maximum(t[:, None], 1e-3)
    # normalize by response length like the LLaDA reference implementation
    denom = jnp.maximum(resp_mask.sum(), 1)
    return -(tok_logp * weight).sum() / denom


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(params, opt_state, cfg: ModelConfig, x0, resp_mask, t, noise,
               lr):
    loss, grads = jax.value_and_grad(mdm_loss)(params, cfg, x0, resp_mask,
                                               t, noise)
    params, opt_state = adamw_update(params, grads, opt_state, lr)
    return params, opt_state, loss


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------

def train_serving_model(cfg: ModelConfig, *, eos_fill: bool, steps: int,
                        batch: int = 32, base_lr: float = 3e-3,
                        seed: int = 0, log_every: int = 200):
    """Train one simulated dLLM on the mixed synthetic corpus."""
    rng = np.random.default_rng(seed)
    params = init_params(rng, cfg)
    opt_state = adamw_init(params)
    t0 = time.time()
    loss_hist = []
    for step in range(steps):
        toks, rmask = D.training_batch(rng, batch, eos_fill=eos_fill)
        t = rng.uniform(0.02, 1.0, size=batch).astype(np.float32)
        noise = rng.uniform(size=toks.shape).astype(np.float32)
        lr = lr_schedule(jnp.asarray(step, jnp.float32), base_lr,
                         warmup=200, total=steps)
        params, opt_state, loss = train_step(
            params, opt_state, cfg, jnp.asarray(toks), jnp.asarray(rmask),
            jnp.asarray(t), jnp.asarray(noise), lr)
        if step % log_every == 0 or step == steps - 1:
            loss_hist.append((step, float(loss)))
            rate = (step + 1) / (time.time() - t0)
            print(f"[{cfg.name}] step {step:5d} loss {float(loss):7.4f} "
                  f"({rate:.1f} steps/s)", flush=True)
    return params, loss_hist


def train_mrf_toy(cfg: ModelConfig, *, steps: int, batch: int = 192,
                  base_lr: float = 2e-3, seed: int = 0, log_every: int = 500):
    """Train one Sec-3.2 toy MDM (all 9 positions maskable, no prompt)."""
    rng = np.random.default_rng(seed)
    params = init_params(rng, cfg)
    opt_state = adamw_init(params)
    rmask = np.ones((batch, cfg.seq_len), np.int32)
    t0 = time.time()
    loss_hist = []
    for step in range(steps):
        toks = D.mrf_sample(rng, batch)
        t = rng.uniform(0.02, 1.0, size=batch).astype(np.float32)
        noise = rng.uniform(size=toks.shape).astype(np.float32)
        lr = lr_schedule(jnp.asarray(step, jnp.float32), base_lr,
                         warmup=100, total=steps)
        params, opt_state, loss = train_step(
            params, opt_state, cfg, jnp.asarray(toks), jnp.asarray(rmask),
            jnp.asarray(t), jnp.asarray(noise), lr)
        if step % log_every == 0 or step == steps - 1:
            loss_hist.append((step, float(loss)))
            rate = (step + 1) / (time.time() - t0)
            print(f"[{cfg.name} s{seed}] step {step:5d} loss "
                  f"{float(loss):7.4f} ({rate:.1f} steps/s)", flush=True)
    return params, loss_hist
