"""Synthetic corpora for the simulated dLLMs.

Each task family is the structural analogue of one of the paper's
evaluation suites (see DESIGN.md "Substitutions"):

  arith      -> GSM8K / Math500 (chained intra-answer dependencies)
  struct     -> HumanEval / MBPP (rigid long-range syntax)
  constraint -> IFEval (verifiable global output constraints)
  multiq     -> the Sec. 6 TriviaQA 5-question aggregation
  pbench-*   -> ParallelBench (copy / reverse / sort / latin / para / w2s)

A generator returns ``(prompt, answer, spec)`` token lists plus a scoring
spec; the same spec format is consumed by ``rust/src/workload``.  World
knowledge (the multiq fact table and the paraphrase bijection) is a fixed
seeded permutation so the model can memorize it during training.
"""

from __future__ import annotations

import numpy as np

from . import vocab as V

# Fixed global "world knowledge", memorized by the model during training.
_WORLD_SEED = 1234


def fact_table() -> list[int]:
    """multiq ground truth: FACT[i] = value index for key i (a bijection)."""
    rng = np.random.default_rng(_WORLD_SEED)
    return [int(x) for x in rng.permutation(V.N_KEYS) % V.N_VALS]


def para_table() -> list[int]:
    """paraphrase ground truth: PARA[i] = word index for word i (bijection)."""
    rng = np.random.default_rng(_WORLD_SEED + 1)
    return [int(x) for x in rng.permutation(V.N_WORDS)]


_FACT = fact_table()
_PARA = para_table()

# ---------------------------------------------------------------------------
# Task generators.  All answers are <= GEN_LEN-1 tokens (room for EOS).
# ---------------------------------------------------------------------------

PROMPT_LEN = 28  # multiq needs 26; everything else is shorter
GEN_LEN = 40
SEQ_LEN = PROMPT_LEN + GEN_LEN


def gen_arith(rng: np.random.Generator):
    """Chained modular arithmetic: the math-reasoning analogue.

    prompt:  <arith> a = 3 ; b = 5 ; c = a + b ; ? c
    answer:  c = 3 + 5 = 8 <eos>         (values mod 10)
    Multi-hop chains substitute previously derived values, so the answer
    tokens form a left-to-right dependency chain like a worked solution.
    """
    n_hops = int(rng.integers(1, 3))  # 1 or 2 derived vars
    v0, v1 = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    names = [int(x) for x in rng.choice(V.N_VARS, size=2 + n_hops, replace=False)]
    prompt = [V.T_ARITH,
              V.var(names[0]), V.EQ, V.digit(v0), V.SEMI,
              V.var(names[1]), V.EQ, V.digit(v1), V.SEMI]
    values = [v0, v1]
    for h in range(n_hops):
        # derived var = previous var + one of the base vars
        lhs = names[2 + h]
        a_idx = 2 + h - 1 if h > 0 else 0
        b_idx = 1
        prompt += [V.var(lhs), V.EQ, V.var(names[a_idx]), V.PLUS,
                   V.var(names[b_idx]), V.SEMI]
        values.append((values[a_idx] + values[b_idx]) % 10)
    ask = 2 + n_hops - 1
    prompt += [V.QM, V.var(names[ask])]
    # Worked answer: final equation with substituted values.
    h = n_hops - 1
    a_idx = 2 + h - 1 if h > 0 else 0
    answer = [V.var(names[ask]), V.EQ, V.digit(values[a_idx]), V.PLUS,
              V.digit(values[1]), V.EQ, V.digit(values[ask])]
    spec = {"task": "arith", "final": values[ask]}
    return prompt, answer, spec


def render_struct(keys, vals, sep_tok):
    answer = [V.LBRACK]
    for i, (k, d) in enumerate(zip(keys, vals)):
        if i:
            answer.append(sep_tok)
        answer += [V.key(k), V.COLON, V.digit(d)]
    answer.append(V.RBRACK)
    return answer


def gen_struct(rng: np.random.Generator):
    """Code-like structured output: the HumanEval/MBPP analogue.

    prompt:  <struct> K3 7 K1 2 K9 5
    answer:  [ K3 : 7 , K1 : 2 , K9 : 5 ] <eos>      (comma dialect)
         or  [ K3 : 7 ; K1 : 2 ; K9 : 5 ] <eos>      (semicolon dialect)

    The separator dialect is sampled uniformly at train time, so each
    separator position is marginally ambiguous while all separators in
    one answer are jointly constrained to agree — the paper's
    joint-marginal mismatch, in miniature.  Scorers accept either
    dialect but require internal consistency.
    """
    n = int(rng.integers(2, 5))
    keys = [int(x) for x in rng.choice(V.N_KEYS, size=n, replace=False)]
    vals = [int(rng.integers(0, 10)) for _ in range(n)]
    prompt = [V.T_STRUCT]
    for k, d in zip(keys, vals):
        prompt += [V.key(k), V.digit(d)]
    sep = V.COMMA if rng.integers(2) == 0 else V.SEMI
    answer = render_struct(keys, vals, sep)
    spec = {"task": "struct", "keys": keys, "vals": vals}
    return prompt, answer, spec


def gen_constraint(rng: np.random.Generator):
    """Exact-count instruction following: the IFEval analogue.

    prompt:  <const> W4 5      answer: W4 W4 W4 W4 W4 <eos>
    """
    w = int(rng.integers(0, V.N_WORDS))
    d = int(rng.integers(2, 7))
    prompt = [V.T_CONST, V.word(w), V.digit(d)]
    answer = [V.word(w)] * d
    spec = {"task": "constraint", "word": w, "count": d}
    return prompt, answer, spec


def gen_multiq(rng: np.random.Generator, n_q: int = 5):
    """Bundled independent fact questions: the Sec. 6 TriviaQA analogue.

    prompt:  <mq> [ 1 ] K7 ? [ 2 ] K2 ? ... (n_q questions)
    answer:  [ 1 ] K7 : V{FACT[7]} <sep> [ 2 ] ... <eos>
    The repeated key token inside each answer segment creates intra-segment
    coupling, while segments are mutually independent given the prompt.
    """
    keys = [int(x) for x in rng.choice(V.N_KEYS, size=n_q, replace=False)]
    prompt = [V.T_MQ]
    for i, k in enumerate(keys):
        prompt += [V.LBRACK, V.digit(i + 1), V.RBRACK, V.key(k), V.QM]
    answer: list[int] = []
    for i, k in enumerate(keys):
        # Each segment independently picks one of two equal-length
        # phrasings, so its bracket/equality tokens are marginally 50/50
        # but jointly coupled *within* the segment — while segments stay
        # mutually independent.  This is the structure the Sec. 6
        # analysis needs (independent questions, internal coupling).
        if rng.integers(2) == 0:
            answer += [V.LBRACK, V.digit(i + 1), V.RBRACK,
                       V.key(k), V.COLON, V.val(_FACT[k])]
        else:
            answer += [V.SEMI, V.digit(i + 1), V.SEMI,
                       V.key(k), V.EQ, V.val(_FACT[k])]
        if i + 1 < n_q:
            answer.append(V.SEP)
    spec = {"task": "multiq", "keys": keys,
            "answers": [_FACT[k] for k in keys]}
    return prompt, answer, spec


def _gen_list(rng, marker, transform, task):
    n = int(rng.integers(4, 7))
    items = [int(x) for x in rng.integers(0, V.N_WORDS, size=n)]
    prompt = [marker] + [V.word(w) for w in items]
    out = transform(items)
    answer = [V.LBRACK] + [V.word(w) for w in out] + [V.RBRACK]
    spec = {"task": task, "items": items, "expect_items": out}
    return prompt, answer, spec


def gen_copy(rng):
    """ParallelBench 'waiting line: copy' — weak inter-token coupling."""
    return _gen_list(rng, V.T_COPY, lambda xs: list(xs), "pbench-copy")


def gen_reverse(rng):
    """ParallelBench 'waiting line: reverse'."""
    return _gen_list(rng, V.T_REV, lambda xs: list(reversed(xs)), "pbench-rev")


def gen_sort(rng):
    """ParallelBench 'waiting line: sort' — global coupling (rank depends
    on every other element)."""
    return _gen_list(rng, V.T_SORT, lambda xs: sorted(xs), "pbench-sort")


def gen_latin(rng: np.random.Generator):
    """Order-3 Latin-square completion: the ParallelBench puzzle analogue.

    prompt gives row 1 and cell (2,1); completion is then unique.
    answer: remaining 5 cells in row-major order, over digits 1..3.
    """
    perm = [int(x) for x in rng.permutation(3)]
    r1 = [p + 1 for p in perm]
    # choose row 2 as a derangement-shift of row 1; cell (2,1) pins which
    r2 = [r1[1], r1[2], r1[0]] if rng.integers(2) == 0 else [r1[2], r1[0], r1[1]]
    r3 = [6 - a - b for a, b in zip(r1, r2)]
    prompt = [V.T_LATIN] + [V.digit(d) for d in r1] + [V.digit(r2[0])]
    answer = [V.digit(d) for d in r2[1:] + r3]
    spec = {"task": "pbench-latin", "row1": r1, "r2c1": r2[0],
            "expect": r2[1:] + r3}
    return prompt, answer, spec


def gen_para(rng: np.random.Generator):
    """Learned word-to-word rewriting: the ParallelBench paraphrase analogue."""
    n = int(rng.integers(3, 6))
    items = [int(x) for x in rng.choice(V.N_WORDS, size=n, replace=False)]
    prompt = [V.T_PARA] + [V.word(w) for w in items]
    out = [_PARA[w] for w in items]
    answer = [V.word(w) for w in out]
    spec = {"task": "pbench-para", "items": items, "expect_items": out}
    return prompt, answer, spec


def gen_w2s(rng: np.random.Generator):
    """Template expansion: the ParallelBench words-to-sentence analogue.

    answer = x y <sep> y x where (x,y) is either prompt order, sampled
    at train time.  Every answer position is marginally 50/50 between
    the two words while the whole answer is one joint choice — the
    hardest coupling pattern for parallel decoding (like ParallelBench's
    paraphrase tasks).
    """
    a, b = (int(x) for x in rng.choice(V.N_WORDS, size=2, replace=False))
    prompt = [V.T_W2S, V.word(a), V.word(b)]
    x, y = (a, b) if rng.integers(2) == 0 else (b, a)
    answer = [V.word(x), V.word(y), V.SEP, V.word(y), V.word(x)]
    spec = {"task": "pbench-w2s", "a": a, "b": b}
    return prompt, answer, spec


GENERATORS = {
    "arith": gen_arith,
    "struct": gen_struct,
    "constraint": gen_constraint,
    "multiq": gen_multiq,
    "pbench-copy": gen_copy,
    "pbench-rev": gen_reverse,
    "pbench-sort": gen_sort,
    "pbench-latin": gen_latin,
    "pbench-para": gen_para,
    "pbench-w2s": gen_w2s,
}

# Sampling mix during training (multiq upweighted: it must memorize facts).
TRAIN_MIX = [
    ("arith", 2.0), ("struct", 2.0), ("constraint", 1.0), ("multiq", 3.0),
    ("pbench-copy", 1.0), ("pbench-rev", 1.0), ("pbench-sort", 1.5),
    ("pbench-latin", 1.0), ("pbench-para", 1.5), ("pbench-w2s", 1.0),
]


def pack_example(prompt, answer, eos_fill: bool, gen_len: int = GEN_LEN,
                 prompt_len: int = PROMPT_LEN):
    """Pack (prompt, answer) into a fixed [SEQ_LEN] row.

    Prompt is right-padded with PAD to ``prompt_len``.  Answer is
    terminated with EOS and padded to ``gen_len`` with EOS (LLaDA-style,
    ``eos_fill=True`` — reproduces EOS overflow) or with FILL after a
    single EOS (Dream-style).
    Returns (tokens[SEQ_LEN], resp_mask[SEQ_LEN]) where resp_mask marks
    positions eligible for diffusion masking (the generation window).
    """
    assert len(prompt) <= prompt_len, f"prompt too long: {len(prompt)}"
    assert len(answer) < gen_len, f"answer too long: {len(answer)}"
    row = list(prompt) + [V.PAD] * (prompt_len - len(prompt))
    ans = list(answer) + [V.EOS]
    pad_tok = V.EOS if eos_fill else V.FILL
    ans += [pad_tok] * (gen_len - len(ans))
    mask = [0] * prompt_len + [1] * gen_len
    return row + ans, mask


def training_batch(rng: np.random.Generator, batch: int, eos_fill: bool,
                   gen_len: int = GEN_LEN, prompt_len: int = PROMPT_LEN):
    """Sample a [batch, SEQ_LEN] tokens array + response mask from the mix."""
    names = [n for n, _ in TRAIN_MIX]
    weights = np.array([w for _, w in TRAIN_MIX])
    weights = weights / weights.sum()
    toks = np.zeros((batch, prompt_len + gen_len), np.int32)
    rmask = np.zeros((batch, prompt_len + gen_len), np.int32)
    for b in range(batch):
        name = names[int(rng.choice(len(names), p=weights))]
        prompt, answer, _ = GENERATORS[name](rng)
        row, m = pack_example(prompt, answer, eos_fill, gen_len, prompt_len)
        toks[b] = row
        rmask[b] = m
    return toks, rmask


def eval_set(task: str, n: int, seed: int, gen_len: int = GEN_LEN,
             prompt_len: int = PROMPT_LEN):
    """Deterministic eval instances for a task, exported to rust."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        prompt, answer, spec = GENERATORS[task](rng)
        padded = list(prompt) + [V.PAD] * (prompt_len - len(prompt))
        out.append({"prompt": padded, "expect": list(answer), "spec": spec})
    return out


# ---------------------------------------------------------------------------
# MRF toy dataset (Sec. 3.2): X1..X5 ~ U{0,1,2}, Y_i = (X_i + X_{i+1}) mod 3.
# Sequence layout: [X1 X2 X3 X4 X5 Y1 Y2 Y3 Y4], vocab {0,1,2} + MASK(=3).
# ---------------------------------------------------------------------------

MRF_LEN = 9
MRF_VOCAB = 4          # values 0,1,2 plus mask id 3
MRF_MASK_ID = 3


def mrf_sample(rng: np.random.Generator, batch: int) -> np.ndarray:
    x = rng.integers(0, 3, size=(batch, 5))
    y = (x[:, :4] + x[:, 1:]) % 3
    return np.concatenate([x, y], axis=1).astype(np.int32)


def mrf_true_edges() -> list[tuple[int, int]]:
    """Ground-truth MRF edges: four triangles {X_i, X_{i+1}, Y_i}."""
    edges = set()
    for i in range(4):
        tri = [i, i + 1, 5 + i]
        for a in range(3):
            for b in range(a + 1, 3):
                edges.add((min(tri[a], tri[b]), max(tri[a], tri[b])))
    return sorted(edges)


def mrf_true_degrees() -> list[int]:
    deg = [0] * MRF_LEN
    for a, b in mrf_true_edges():
        deg[a] += 1
        deg[b] += 1
    return deg


if __name__ == "__main__":
    import sys

    rng = np.random.default_rng(0)
    if "--show-mrf" in sys.argv:
        print("MRF edges:", mrf_true_edges())
        print("MRF degrees:", mrf_true_degrees())
        print("sample:", mrf_sample(rng, 2))
        sys.exit(0)
    for name, gen in GENERATORS.items():
        p, a, s = gen(rng)
        print(f"[{name}] prompt: {V.detok(p)}")
        print(f"[{name}] answer: {V.detok(a)}")
