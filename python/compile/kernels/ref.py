"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has an oracle here with an identical
signature; ``python/tests/test_kernels.py`` sweeps shapes/dtypes with
hypothesis and asserts allclose between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, bias=None):
    """Multi-head attention returning (context, probs).

    q, k, v: [B, H, L, Dh].  bias: optional [B, 1|H, L, L] additive logits
    bias (used for PAD masking).  Returns context [B, H, L, Dh] and probs
    [B, H, L, L].
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhld,bhmd->bhlm", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype))
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhlm,bhmd->bhld", probs, v)
    return ctx, probs


def edge_scores_ref(attn, masked):
    """Symmetrized, masked-pair edge scores + proxy degrees.

    attn:   [B, L, L] layer/head-averaged attention (rows ~ sum to 1).
    masked: [B, L] float {0,1}; 1 where the position is still [M].

    Returns (scores [B, L, L], degrees [B, L]) where
      scores[b,i,j] = 0.5*(a_ij + a_ji) * masked_i * masked_j, zero diag;
      degrees[b,i]  = sum_j scores[b,i,j]   (the paper's proxy degree).
    """
    b, l, _ = attn.shape
    sym = 0.5 * (attn + jnp.swapaxes(attn, 1, 2))
    pair = masked[:, :, None] * masked[:, None, :]
    eye = jnp.eye(l, dtype=attn.dtype)[None]
    scores = sym * pair * (1.0 - eye)
    degrees = scores.sum(axis=-1)
    return scores, degrees
