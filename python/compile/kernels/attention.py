"""L1 Pallas kernel: fused multi-head attention that also emits the
attention probabilities.

DAPD's whole point is that the dependency signal (attention) is reused
from the forward pass, so the kernel must materialize the probability
matrix in addition to the context — a flash-attention-style two-pass
running softmax would discard it.  Instead we tile over (batch, head) and
keep the full (Lq x Lk) score tile resident in VMEM:

  * grid = (B, H): one program instance per (batch, head) pair;
  * BlockSpec keeps q/k/v [L, Dh] tiles and the [L, L] score tile in VMEM.
    For the model sizes served here (L <= 256, Dh <= 32) the footprint is
    L*Dh*3*4 + L*L*4 bytes < 300 KiB, well under the ~16 MiB VMEM budget —
    see DESIGN.md "Hardware adaptation" for the roofline estimate;
  * the Lq x Lk matmul and the probs @ v matmul are MXU-shaped
    (contraction over Dh and Lk respectively);
  * softmax is computed in f32 with the usual max-subtraction.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (numerically identical).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, ctx_ref, probs_ref):
    """One (batch, head) tile: full-L attention in VMEM."""
    q = q_ref[0, 0]          # [L, Dh]
    k = k_ref[0, 0]          # [L, Dh]
    v = v_ref[0, 0]          # [L, Dh]
    bias = bias_ref[0, 0]    # [L, L]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    # MXU matmul: [L, Dh] @ [Dh, L] -> [L, L] score tile (f32 accumulate).
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + bias
    # Numerically-stable row softmax, all in VMEM.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / z
    probs_ref[0, 0] = probs.astype(probs_ref.dtype)
    # Second MXU matmul: [L, L] @ [L, Dh] -> context.
    ctx_ref[0, 0] = jnp.dot(probs, v,
                            preferred_element_type=jnp.float32
                            ).astype(ctx_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def attention(q, k, v, bias=None):
    """Fused MHA returning (context, probs); Pallas, interpret mode.

    Same contract as ``ref.attention_ref``: q/k/v [B, H, L, Dh], optional
    additive bias [B, 1, L, L] or [B, H, L, L].
    """
    b, h, l, dh = q.shape
    if bias is None:
        bias = jnp.zeros((b, 1, l, l), q.dtype)
    if bias.shape[1] == 1 and h > 1:
        bias = jnp.broadcast_to(bias, (b, h, l, l))

    blk_qkv = pl.BlockSpec((1, 1, l, dh), lambda i, j: (i, j, 0, 0))
    blk_ll = pl.BlockSpec((1, 1, l, l), lambda i, j: (i, j, 0, 0))
    ctx, probs = pl.pallas_call(
        _attn_kernel,
        grid=(b, h),
        in_specs=[blk_qkv, blk_qkv, blk_qkv, blk_ll],
        out_specs=[blk_qkv, blk_ll],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, dh), q.dtype),
            jax.ShapeDtypeStruct((b, h, l, l), q.dtype),
        ],
        interpret=True,
    )(q, k, v, bias)
    return ctx, probs
