"""L1 Pallas kernel: fused edge-score + proxy-degree computation.

The DAPD hot loop consumes, at every decoding step,

    s_ij = 0.5 * (a_ij + a_ji)   restricted to masked pairs, zero diag
    d~_i = sum_j s_ij            (the Welsh-Powell proxy degree)

A naive implementation is three O(L^2) passes (transpose-add, pair mask,
row reduce) with three HBM round-trips.  This kernel fuses them into one
pass over a single [L, L] VMEM tile per batch element: the tile is read
once, symmetrized in-register, masked, written once, and the row
reduction falls out of the same tile.  BlockSpec expresses exactly the
HBM<->VMEM schedule a CUDA version would express with threadblocks.

``interpret=True``: lowers to plain HLO for CPU PJRT (see attention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_kernel(attn_ref, masked_ref, scores_ref, deg_ref):
    a = attn_ref[0]          # [L, L]
    m = masked_ref[0]        # [L]
    l = a.shape[0]
    sym = 0.5 * (a + a.T)
    pair = m[:, None] * m[None, :]
    # zero the diagonal without materializing an eye() in HBM
    row = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    off_diag = (row != col).astype(sym.dtype)
    s = sym * pair * off_diag
    scores_ref[0] = s.astype(scores_ref.dtype)
    deg_ref[0] = jnp.sum(s, axis=-1).astype(deg_ref.dtype)


def edge_scores(attn, masked):
    """Fused (scores, degrees) from averaged attention; Pallas, interpret.

    Same contract as ``ref.edge_scores_ref``: attn [B, L, L],
    masked [B, L] float {0,1}.
    """
    b, l, _ = attn.shape
    blk_ll = pl.BlockSpec((1, l, l), lambda i: (i, 0, 0))
    blk_l = pl.BlockSpec((1, l), lambda i: (i, 0))
    scores, deg = pl.pallas_call(
        _edge_kernel,
        grid=(b,),
        in_specs=[blk_ll, blk_l],
        out_specs=[blk_ll, blk_l],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, l), attn.dtype),
            jax.ShapeDtypeStruct((b, l), attn.dtype),
        ],
        interpret=True,
    )(attn, masked)
    return scores, deg
