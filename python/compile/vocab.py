"""Shared vocabulary for the synthetic serving corpus.

The same token-id table is exported to ``artifacts/metadata.json`` so the
Rust coordinator and the Python trainer agree exactly on tokenization.
dLLM substitution note (see DESIGN.md): LLaDA/Dream use a 126k/152k BPE
vocab; our simulated models use a closed ~100-token vocabulary because the
tasks are synthetic.  Nothing in DAPD depends on vocabulary size beyond
softmax cost.
"""

from __future__ import annotations

# --- special tokens -------------------------------------------------------
PAD = 0      # inert padding (prompt right-pad)
MASK = 1     # the [M] diffusion mask token
EOS = 2      # end of answer; LLaDA-style models pad answers with EOS
BOS = 3
SEP = 4      # generic separator inside answers
QM = 5       # "?" question marker
FILL = 6     # neutral filler: Dream-style models pad answers with FILL

LBRACK = 7
RBRACK = 8
COLON = 9
COMMA = 10
PLUS = 11
EQ = 12
SEMI = 13

# --- digits 0..9 ----------------------------------------------------------
DIGIT0 = 14
N_DIGITS = 10


def digit(d: int) -> int:
    assert 0 <= d < N_DIGITS
    return DIGIT0 + d


# --- variable names a..j --------------------------------------------------
VAR0 = DIGIT0 + N_DIGITS  # 24
N_VARS = 10


def var(i: int) -> int:
    assert 0 <= i < N_VARS
    return VAR0 + i


# --- fact keys / values (multiq world knowledge) --------------------------
KEY0 = VAR0 + N_VARS  # 34
N_KEYS = 16


def key(i: int) -> int:
    assert 0 <= i < N_KEYS
    return KEY0 + i


VAL0 = KEY0 + N_KEYS  # 50
N_VALS = 16


def val(i: int) -> int:
    assert 0 <= i < N_VALS
    return VAL0 + i


# --- generic words --------------------------------------------------------
WORD0 = VAL0 + N_VALS  # 66
N_WORDS = 16


def word(i: int) -> int:
    assert 0 <= i < N_WORDS
    return WORD0 + i


# --- task-type markers (first prompt token) -------------------------------
T_ARITH = WORD0 + N_WORDS  # 82
T_STRUCT = 83
T_CONST = 84
T_MQ = 85
T_COPY = 86
T_REV = 87
T_SORT = 88
T_LATIN = 89
T_PARA = 90
T_W2S = 91

VOCAB_SIZE = 92

_SPECIAL_NAMES = {
    PAD: "<pad>", MASK: "<mask>", EOS: "<eos>", BOS: "<bos>", SEP: "<sep>",
    QM: "?", FILL: "<fill>", LBRACK: "[", RBRACK: "]", COLON: ":",
    COMMA: ",", PLUS: "+", EQ: "=", SEMI: ";",
    T_ARITH: "<arith>", T_STRUCT: "<struct>", T_CONST: "<const>",
    T_MQ: "<mq>", T_COPY: "<copy>", T_REV: "<rev>", T_SORT: "<sort>",
    T_LATIN: "<latin>", T_PARA: "<para>", T_W2S: "<w2s>",
}


def token_name(t: int) -> str:
    """Human-readable token name (debugging and metadata export)."""
    if t in _SPECIAL_NAMES:
        return _SPECIAL_NAMES[t]
    if DIGIT0 <= t < DIGIT0 + N_DIGITS:
        return str(t - DIGIT0)
    if VAR0 <= t < VAR0 + N_VARS:
        return chr(ord("a") + t - VAR0)
    if KEY0 <= t < KEY0 + N_KEYS:
        return f"K{t - KEY0}"
    if VAL0 <= t < VAL0 + N_VALS:
        return f"V{t - VAL0}"
    if WORD0 <= t < WORD0 + N_WORDS:
        return f"W{t - WORD0}"
    return f"<unk{t}>"


def vocab_table() -> dict[str, int]:
    """name -> id map for metadata.json."""
    return {token_name(t): t for t in range(VOCAB_SIZE)}


def detok(tokens) -> str:
    return " ".join(token_name(int(t)) for t in tokens)
