"""L2: the masked-diffusion transformer (LLaDA/RADD-style), pure JAX.

Bidirectional (no causal mask) pre-LN transformer with learned positional
embeddings and a GELU MLP.  Like RADD/LLaDA, there is no explicit time
conditioning: the mask pattern itself carries the diffusion state.

The forward pass exposes exactly what the Rust coordinator needs:

  * ``serving_forward``  -> (logits, attn_avg, edge_scores, degrees)
      attn_avg averages heads over the final 30% of layers (the paper's
      Sec. 4.3 choice) and the L1 ``edge_scores`` kernel pre-computes the
      symmetrized masked pair scores + proxy degrees on-device, so L3
      only does thresholding + Welsh-Powell.
  * ``toy_forward``      -> (logits, attn_layers[B, n_layers, L, L])
      per-layer head-averaged attention for the Sec. 3.2 MRF validation
      and the Table 10 layer-selection ablation.

``use_pallas=True`` routes the attention core and edge computation
through the L1 Pallas kernels (what the AOT artifacts use);
``use_pallas=False`` uses the jnp oracles (what the trainer uses — the
two paths are asserted numerically identical in python/tests).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels.attention import attention as pallas_attention
from .kernels.edge_scores import edge_scores as pallas_edge_scores


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + decoding-relevant constants for one model variant."""

    name: str
    vocab: int
    seq_len: int           # maximum (training) sequence length
    d_model: int
    n_heads: int
    n_layers: int
    mlp_ratio: int = 4
    mask_id: int = 1       # vocab id of [M]
    pad_id: int = 0        # vocab id of <pad> (key-masked in attention)
    # fraction of final layers whose attention feeds the dependency graph
    attn_layer_frac: float = 0.3
    # init scale for W_q/W_k: at 0.02 the q.k logits start ~1e-2 and the
    # softmax is uniform ("lazy attention") — fine for the rich serving
    # corpus, but the mod-3 toy (a grokking-style task) needs a larger
    # scale to get first-order attention gradients within the CPU budget.
    attn_init_scale: float = 0.02

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def graph_layers(self) -> list[int]:
        """Indices of the final ceil(frac * n_layers) layers (Sec. 4.3)."""
        k = max(1, math.ceil(self.attn_layer_frac * self.n_layers))
        return list(range(self.n_layers - k, self.n_layers))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(rng: np.random.Generator, cfg: ModelConfig) -> dict:
    """Gaussian init scaled like GPT-2 (0.02, residual-scaled output projs)."""
    d, v, l = cfg.d_model, cfg.vocab, cfg.seq_len

    def norm(*shape, scale=0.02):
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    res_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    params = {
        "tok_emb": norm(v, d),
        "pos_emb": norm(l, d),
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "head": norm(d, v),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "wq": norm(d, d, scale=cfg.attn_init_scale),
            "wk": norm(d, d, scale=cfg.attn_init_scale),
            "wv": norm(d, d),
            "wo": norm(d, d, scale=res_scale),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "w1": norm(d, cfg.mlp_ratio * d),
            "b1": jnp.zeros((cfg.mlp_ratio * d,), jnp.float32),
            "w2": norm(cfg.mlp_ratio * d, d, scale=res_scale),
            "b2": jnp.zeros((d,), jnp.float32),
        })
    return params


def params_to_flat(params: dict) -> dict[str, np.ndarray]:
    """Flatten to name->array (npz caching)."""
    flat = {k: np.asarray(v) for k, v in params.items() if k != "layers"}
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            flat[f"layers.{i}.{k}"] = np.asarray(v)
    return flat


def params_from_flat(flat: dict, cfg: ModelConfig) -> dict:
    params = {k: jnp.asarray(v) for k, v in flat.items() if "." not in k}
    params["layers"] = []
    for i in range(cfg.n_layers):
        layer = {}
        prefix = f"layers.{i}."
        for k, v in flat.items():
            if k.startswith(prefix):
                layer[k[len(prefix):]] = jnp.asarray(v)
        params["layers"].append(layer)
    return params


def count_params(params: dict) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def forward(params, cfg: ModelConfig, tokens, use_pallas: bool,
            seq_len: int | None = None):
    """Backbone forward.

    tokens: [B, L] int32 with L == seq_len (defaults to cfg.seq_len; a
    shorter L slices the positional table, used for the Table 7 length
    sweep).  Returns (logits [B, L, V], attns [n_layers, B, L, L]) with
    attns head-averaged per layer.
    """
    l = seq_len or cfg.seq_len
    b = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.d_head

    x = params["tok_emb"][tokens] + params["pos_emb"][None, :l]
    # Key-side PAD mask: PAD positions receive no attention mass.
    if cfg.pad_id >= 0:
        pad = tokens == cfg.pad_id
        bias = jnp.where(pad[:, None, None, :], -1e9, 0.0)
        bias = bias.astype(jnp.float32)
    else:
        bias = jnp.zeros((b, 1, 1, l), jnp.float32)
    bias = jnp.broadcast_to(bias, (b, 1, l, l))

    attn_fn = pallas_attention if use_pallas else kref.attention_ref
    attns = []
    for layer in params["layers"]:
        y = _layer_norm(x, layer["ln1_g"], layer["ln1_b"])
        q = (y @ layer["wq"]).reshape(b, l, h, dh).transpose(0, 2, 1, 3)
        k = (y @ layer["wk"]).reshape(b, l, h, dh).transpose(0, 2, 1, 3)
        v = (y @ layer["wv"]).reshape(b, l, h, dh).transpose(0, 2, 1, 3)
        ctx, probs = attn_fn(q, k, v, bias)
        attns.append(probs.mean(axis=1))  # head-average -> [B, L, L]
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, l, cfg.d_model)
        x = x + ctx @ layer["wo"]
        y = _layer_norm(x, layer["ln2_g"], layer["ln2_b"])
        y = jax.nn.gelu(y @ layer["w1"] + layer["b1"]) @ layer["w2"]
        x = x + y + layer["b2"]
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["head"]
    return logits, jnp.stack(attns)  # [n_layers, B, L, L]


def serving_forward(params, cfg: ModelConfig, tokens, use_pallas: bool = True,
                    seq_len: int | None = None):
    """The AOT-exported request-path function.

    Returns (logits, attn_avg, edge_scores, degrees):
      attn_avg   [B, L, L]  head-avg over the final-30% layers,
      edge_scores[B, L, L]  symmetrized masked pair scores (L1 kernel),
      degrees    [B, L]     proxy degrees d~_i.
    """
    logits, attns = forward(params, cfg, tokens, use_pallas, seq_len)
    sel = cfg.graph_layers()
    attn_avg = attns[jnp.asarray(sel)].mean(axis=0)
    masked = (tokens == cfg.mask_id).astype(attn_avg.dtype)
    edge_fn = pallas_edge_scores if use_pallas else kref.edge_scores_ref
    scores, degrees = edge_fn(attn_avg, masked)
    return logits, attn_avg, scores, degrees


def toy_forward(params, cfg: ModelConfig, tokens, use_pallas: bool = True):
    """The MRF-validation export: per-layer attention for layer ablations.

    Returns (logits [B, L, V], attn_layers [B, n_layers, L, L]).
    """
    logits, attns = forward(params, cfg, tokens, use_pallas)
    return logits, attns.transpose(1, 0, 2, 3)


# ---------------------------------------------------------------------------
# Model zoo (see DESIGN.md substitutions)
# ---------------------------------------------------------------------------

def model_zoo() -> dict[str, ModelConfig]:
    from . import datasets as D
    from . import vocab as V

    return {
        # Model sizes are calibrated to the single-core CPU testbed (see
        # DESIGN.md): ~250k params trains in minutes while still learning
        # every task family and exhibiting structured attention.
        # LLaDA stand-in: deeper, EOS-filled training (EOS overflow emerges)
        "sim-llada": ModelConfig(
            name="sim-llada", vocab=V.VOCAB_SIZE, seq_len=D.SEQ_LEN,
            d_model=64, n_heads=4, n_layers=5,
            mask_id=V.MASK, pad_id=V.PAD),
        # Dream stand-in: shallower, FILL-padded training
        "sim-dream": ModelConfig(
            name="sim-dream", vocab=V.VOCAB_SIZE, seq_len=D.SEQ_LEN,
            d_model=64, n_heads=4, n_layers=4,
            mask_id=V.MASK, pad_id=V.PAD),
        # Sec 3.2 toy: 8 transformer blocks like the paper's DiT/RADD setup.
        # attn_init_scale breaks the lazy-attention plateau of the mod-3
        # constraint task (a grokking-style objective) within CPU budget.
        "mrf-toy": ModelConfig(
            name="mrf-toy", vocab=D.MRF_VOCAB, seq_len=D.MRF_LEN,
            d_model=32, n_heads=4, n_layers=8,
            mask_id=D.MRF_MASK_ID, pad_id=-1,  # toy has no PAD token
            attn_init_scale=0.15),
    }
