"""L2 model tests: shapes, pallas/jnp parity, masking semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as D
from compile import vocab as V
from compile.model import (ModelConfig, count_params, forward, init_params,
                           model_zoo, params_from_flat, params_to_flat,
                           serving_forward, toy_forward)

TINY = ModelConfig(name="tiny", vocab=V.VOCAB_SIZE, seq_len=20, d_model=16,
                   n_heads=2, n_layers=3, mask_id=V.MASK, pad_id=V.PAD)


def tiny_params(seed=0):
    return init_params(np.random.default_rng(seed), TINY)


def tokens(rng, b, l, vocab):
    return jnp.asarray(rng.integers(2, vocab, size=(b, l)), jnp.int32)


def test_forward_shapes():
    p = tiny_params()
    rng = np.random.default_rng(0)
    toks = tokens(rng, 2, 20, TINY.vocab)
    logits, attns = forward(p, TINY, toks, use_pallas=False)
    assert logits.shape == (2, 20, TINY.vocab)
    assert attns.shape == (TINY.n_layers, 2, 20, 20)


def test_pallas_and_jnp_paths_agree():
    p = tiny_params()
    rng = np.random.default_rng(1)
    toks = tokens(rng, 2, 20, TINY.vocab)
    lg1, at1 = forward(p, TINY, toks, use_pallas=False)
    lg2, at2 = forward(p, TINY, toks, use_pallas=True)
    np.testing.assert_allclose(lg1, lg2, atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(at1, at2, atol=1e-5, rtol=1e-4)


def test_serving_forward_shapes_and_outputs():
    p = tiny_params()
    rng = np.random.default_rng(2)
    toks = np.array(tokens(rng, 2, 20, TINY.vocab))
    toks[:, 10:] = TINY.mask_id
    lg, attn_avg, scores, deg = serving_forward(p, TINY, jnp.asarray(toks),
                                                use_pallas=False)
    assert lg.shape == (2, 20, TINY.vocab)
    assert attn_avg.shape == (2, 20, 20)
    assert scores.shape == (2, 20, 20)
    assert deg.shape == (2, 20)
    s = np.asarray(scores)
    # scores only among masked pairs (positions 10..19)
    assert np.abs(s[:, :10, :]).max() == 0.0
    assert np.abs(s[:, :, :10]).max() == 0.0
    assert s[:, 10:, 10:].max() > 0.0


def test_serving_forward_pallas_parity():
    p = tiny_params()
    rng = np.random.default_rng(3)
    toks = np.array(tokens(rng, 1, 20, TINY.vocab))
    toks[:, 12:] = TINY.mask_id
    outs_a = serving_forward(p, TINY, jnp.asarray(toks), use_pallas=False)
    outs_b = serving_forward(p, TINY, jnp.asarray(toks), use_pallas=True)
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-4)


def test_toy_forward_layout():
    cfg = model_zoo()["mrf-toy"]
    p = init_params(np.random.default_rng(0), cfg)
    toks = jnp.asarray(np.zeros((3, cfg.seq_len), np.int32))
    lg, attns = toy_forward(p, cfg, toks, use_pallas=False)
    assert lg.shape == (3, cfg.seq_len, cfg.vocab)
    assert attns.shape == (3, cfg.n_layers, cfg.seq_len, cfg.seq_len)


def test_pad_receives_no_attention():
    p = tiny_params()
    rng = np.random.default_rng(4)
    toks = np.array(tokens(rng, 1, 20, TINY.vocab))
    toks[0, 5:8] = V.PAD
    _, attns = forward(p, TINY, jnp.asarray(toks), use_pallas=False)
    a = np.asarray(attns)  # [layers, B, L, L]
    assert a[:, 0, :, 5:8].max() < 1e-6


def test_seq_len_slicing():
    """Shorter seq_len slices the positional table (Table 7 sweep)."""
    p = tiny_params()
    rng = np.random.default_rng(5)
    toks = tokens(rng, 1, 12, TINY.vocab)
    logits, attns = forward(p, TINY, toks, use_pallas=False, seq_len=12)
    assert logits.shape == (1, 12, TINY.vocab)
    assert attns.shape == (TINY.n_layers, 1, 12, 12)


def test_params_flat_roundtrip():
    p = tiny_params()
    flat = params_to_flat(p)
    p2 = params_from_flat(flat, TINY)
    rng = np.random.default_rng(6)
    toks = tokens(rng, 1, 20, TINY.vocab)
    lg1, _ = forward(p, TINY, toks, use_pallas=False)
    lg2, _ = forward(p2, TINY, toks, use_pallas=False)
    np.testing.assert_allclose(lg1, lg2)


def test_graph_layers_last_30pct():
    zoo = model_zoo()
    for cfg in zoo.values():
        gl = cfg.graph_layers()
        assert gl, cfg.name
        assert max(gl) == cfg.n_layers - 1
        assert len(gl) == max(1, int(np.ceil(0.3 * cfg.n_layers)))
        assert gl == sorted(gl)


def test_count_params_positive():
    assert count_params(tiny_params()) > 1000
