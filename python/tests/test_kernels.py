"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref.py is THE
correctness signal for the kernels that end up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.edge_scores import edge_scores

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 3), h=st.integers(1, 4),
       l=st.sampled_from([1, 4, 9, 17, 32]), dh=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_attention_matches_ref(b, h, l, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, b, h, l, dh), rand(rng, b, h, l, dh), rand(rng, b, h, l, dh)
    ctx, probs = attention(q, k, v)
    ctx_r, probs_r = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(ctx, ctx_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(probs, probs_r, atol=1e-6, rtol=1e-5)


@given(b=st.integers(1, 2), h=st.integers(1, 3), l=st.sampled_from([4, 12]),
       seed=st.integers(0, 2**31 - 1), per_head=st.booleans())
def test_attention_with_bias(b, h, l, seed, per_head):
    rng = np.random.default_rng(seed)
    dh = 8
    q, k, v = rand(rng, b, h, l, dh), rand(rng, b, h, l, dh), rand(rng, b, h, l, dh)
    bias = rand(rng, b, h if per_head else 1, l, l)
    ctx, probs = attention(q, k, v, bias)
    ctx_r, probs_r = ref.attention_ref(q, k, v, bias)
    np.testing.assert_allclose(ctx, ctx_r, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(probs, probs_r, atol=1e-6, rtol=1e-5)


def test_attention_rows_sum_to_one():
    rng = np.random.default_rng(0)
    q, k, v = (rand(rng, 2, 2, 16, 8) for _ in range(3))
    _, probs = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(probs).sum(-1),
                               np.ones((2, 2, 16)), atol=1e-5)


def test_attention_key_masking_bias():
    """-1e9 bias on a key column removes all attention to it."""
    rng = np.random.default_rng(1)
    b, h, l, dh = 1, 2, 8, 8
    q, k, v = (rand(rng, b, h, l, dh) for _ in range(3))
    bias = np.zeros((b, 1, l, l), np.float32)
    bias[..., 3] = -1e9
    _, probs = attention(q, k, v, jnp.asarray(bias))
    assert float(np.asarray(probs)[..., 3].max()) < 1e-6


# ---------------------------------------------------------------------------
# edge-score kernel
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 4), l=st.sampled_from([2, 5, 9, 16, 40]),
       seed=st.integers(0, 2**31 - 1))
def test_edge_scores_match_ref(b, l, seed):
    rng = np.random.default_rng(seed)
    attn = jnp.asarray(rng.random((b, l, l)), jnp.float32)
    masked = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    s, d = edge_scores(attn, masked)
    s_r, d_r = ref.edge_scores_ref(attn, masked)
    np.testing.assert_allclose(s, s_r, atol=1e-6, rtol=1e-5)
    np.testing.assert_allclose(d, d_r, atol=1e-5, rtol=1e-5)


@given(b=st.integers(1, 3), l=st.sampled_from([3, 9, 24]),
       seed=st.integers(0, 2**31 - 1))
def test_edge_scores_invariants(b, l, seed):
    """Symmetric, zero diagonal, zero on unmasked pairs, degrees = row sums."""
    rng = np.random.default_rng(seed)
    attn = jnp.asarray(rng.random((b, l, l)), jnp.float32)
    masked = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    s, d = edge_scores(attn, masked)
    s = np.asarray(s)
    np.testing.assert_allclose(s, np.swapaxes(s, 1, 2), atol=1e-6)
    assert np.abs(np.diagonal(s, axis1=1, axis2=2)).max() == 0.0
    m = np.asarray(masked)
    pair = m[:, :, None] * m[:, None, :]
    assert np.abs(s * (1 - pair)).max() == 0.0
    np.testing.assert_allclose(np.asarray(d), s.sum(-1), atol=1e-5)


def test_edge_scores_all_masked_uniform():
    """Uniform attention, all masked -> every degree = (L-1)/L."""
    l = 10
    attn = jnp.full((1, l, l), 1.0 / l, jnp.float32)
    masked = jnp.ones((1, l), jnp.float32)
    _, d = edge_scores(attn, masked)
    np.testing.assert_allclose(np.asarray(d)[0], np.full(l, (l - 1) / l),
                               atol=1e-6)
