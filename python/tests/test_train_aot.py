"""Trainer + AOT pipeline tests (tiny configs, CPU-cheap)."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as D
from compile import vocab as V
from compile.aot import lower_serving, lower_toy, to_hlo_text
from compile.model import ModelConfig, init_params, model_zoo, serving_forward
from compile.train import (adamw_init, adamw_update, lr_schedule, mdm_loss,
                           train_step)

TINY = ModelConfig(name="tiny", vocab=V.VOCAB_SIZE, seq_len=D.SEQ_LEN,
                   d_model=16, n_heads=2, n_layers=2,
                   mask_id=V.MASK, pad_id=V.PAD)


def test_adamw_moves_params():
    p = init_params(np.random.default_rng(0), TINY)
    st = adamw_init(p)
    g = {k: (jnp.ones_like(v) if k != "layers" else v)
         for k, v in p.items()}
    g["layers"] = [{k: jnp.ones_like(v) for k, v in layer.items()}
                   for layer in p["layers"]]
    p2, st2 = adamw_update(p, g, st, lr=1e-2)
    assert float(jnp.abs(p2["tok_emb"] - p["tok_emb"]).max()) > 0
    assert int(st2["step"]) == 1


def test_lr_schedule_shape():
    lrs = [float(lr_schedule(jnp.asarray(float(s)), 1e-3, 10, 100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup ascends
    assert lrs[50] > lrs[99]                        # cosine decays
    assert lrs[99] >= 0


def test_mdm_loss_masks_only_response():
    """Loss is invariant to prompt content at unmasked positions only
    through conditioning; masked positions are all in the response."""
    p = init_params(np.random.default_rng(0), TINY)
    rng = np.random.default_rng(1)
    toks, rmask = D.training_batch(rng, 4, eos_fill=True)
    t = np.full(4, 0.5, np.float32)
    noise = rng.uniform(size=toks.shape).astype(np.float32)
    loss = mdm_loss(p, TINY, jnp.asarray(toks), jnp.asarray(rmask),
                    jnp.asarray(t), jnp.asarray(noise))
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_train_step_reduces_loss():
    """A few steps on a fixed batch should reduce the loss (smoke)."""
    p = init_params(np.random.default_rng(0), TINY)
    st = adamw_init(p)
    rng = np.random.default_rng(2)
    toks, rmask = D.training_batch(rng, 16, eos_fill=True)
    t = np.full(16, 0.5, np.float32)
    noise = rng.uniform(size=toks.shape).astype(np.float32)
    args = (jnp.asarray(toks), jnp.asarray(rmask), jnp.asarray(t),
            jnp.asarray(noise))
    first = None
    for i in range(8):
        p, st, loss = train_step(p, st, TINY, *args, jnp.asarray(3e-3))
        if first is None:
            first = float(loss)
    assert float(loss) < first


def test_hlo_text_has_constants_and_tuple():
    """Regression for the two interchange gotchas: elided constants and
    non-tuple outputs."""
    p = init_params(np.random.default_rng(0), TINY)
    text = lower_serving(p, TINY, batch=1, gen_len=8)
    assert "constant({...})" not in text            # weights actually baked
    assert "f32[" in text and "s32[1,36]" in text   # 28 prompt + 8 gen
    # 4-tuple output signature
    assert text.count("ROOT") >= 1


def test_lower_toy_shapes():
    cfg = ModelConfig(name="toy-tiny", vocab=D.MRF_VOCAB, seq_len=D.MRF_LEN,
                      d_model=16, n_heads=2, n_layers=2,
                      mask_id=D.MRF_MASK_ID, pad_id=-1)
    p = init_params(np.random.default_rng(0), cfg)
    text = lower_toy(p, cfg, batch=2)
    assert "s32[2,9]" in text
    assert "constant({...})" not in text
