"""Dataset invariants: packing, determinism, task semantics."""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets as D
from compile import vocab as V

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(seed=st.integers(0, 2**31 - 1),
       task=st.sampled_from(sorted(D.GENERATORS)))
def test_generator_bounds(seed, task):
    rng = np.random.default_rng(seed)
    prompt, answer, spec = D.GENERATORS[task](rng)
    assert len(prompt) <= D.PROMPT_LEN
    assert len(answer) < D.GEN_LEN
    assert all(0 <= t < V.VOCAB_SIZE for t in prompt + answer)
    assert V.MASK not in prompt and V.MASK not in answer
    assert V.EOS not in answer
    assert spec["task"] == task or spec["task"].startswith("pbench")


@given(seed=st.integers(0, 2**31 - 1), eos_fill=st.booleans())
def test_pack_example(seed, eos_fill):
    rng = np.random.default_rng(seed)
    prompt, answer, _ = D.gen_struct(rng)
    row, mask = D.pack_example(prompt, answer, eos_fill)
    assert len(row) == D.SEQ_LEN and len(mask) == D.SEQ_LEN
    assert row[:len(prompt)] == prompt
    assert all(t == V.PAD for t in row[len(prompt):D.PROMPT_LEN])
    gen = row[D.PROMPT_LEN:]
    assert gen[:len(answer)] == answer
    assert gen[len(answer)] == V.EOS
    pad_tok = V.EOS if eos_fill else V.FILL
    assert all(t == pad_tok for t in gen[len(answer) + 1:])
    assert mask == [0] * D.PROMPT_LEN + [1] * D.GEN_LEN


def test_training_batch_shapes():
    rng = np.random.default_rng(0)
    toks, rmask = D.training_batch(rng, 8, eos_fill=True)
    assert toks.shape == (8, D.SEQ_LEN) and rmask.shape == (8, D.SEQ_LEN)
    assert toks.dtype == np.int32
    # the generation window of an eos_fill batch always ends with EOS runs
    assert (toks[:, -1] == V.EOS).all()


def test_eval_set_deterministic_and_json_clean():
    a = D.eval_set("multiq", 5, seed=42)
    b = D.eval_set("multiq", 5, seed=42)
    assert json.dumps(a) == json.dumps(b)
    c = D.eval_set("multiq", 5, seed=43)
    assert json.dumps(a) != json.dumps(c)


def test_fact_and_para_are_deterministic_bijections():
    f1, f2 = D.fact_table(), D.fact_table()
    assert f1 == f2
    assert sorted(set(f1)) == sorted(set(f1))  # values in range
    p1 = D.para_table()
    assert sorted(p1) == list(range(V.N_WORDS))  # a permutation


def test_multiq_answers_follow_fact_table():
    rng = np.random.default_rng(7)
    fact = D.fact_table()
    _, answer, spec = D.gen_multiq(rng)
    assert spec["answers"] == [fact[k] for k in spec["keys"]]
    # each segment contains key then its value
    for i, k in enumerate(spec["keys"]):
        assert V.key(k) in answer
        assert V.val(fact[k]) in answer


def test_arith_chain_is_consistent():
    rng = np.random.default_rng(3)
    for _ in range(50):
        prompt, answer, spec = D.gen_arith(rng)
        # answer: var = d1 + d2 = final
        assert answer[1] == V.EQ and answer[-2] == V.EQ
        d1 = answer[2] - V.DIGIT0
        d2 = answer[4] - V.DIGIT0
        final = answer[-1] - V.DIGIT0
        assert (d1 + d2) % 10 == final == spec["final"]


def test_latin_completion_valid():
    rng = np.random.default_rng(4)
    for _ in range(50):
        _, answer, spec = D.gen_latin(rng)
        r1 = spec["row1"]
        cells = [spec["r2c1"]] + [t - V.DIGIT0 for t in answer]
        r2, r3 = cells[:3], cells[3:]
        grid = [r1, r2, r3]
        for row in grid:
            assert sorted(row) == [1, 2, 3]
        for col in zip(*grid):
            assert sorted(col) == [1, 2, 3]


def test_sort_task_sorted():
    rng = np.random.default_rng(5)
    _, answer, spec = D.gen_sort(rng)
    inner = [t - V.WORD0 for t in answer[1:-1]]
    assert inner == sorted(spec["items"])


def test_para_task_applies_table():
    rng = np.random.default_rng(6)
    tbl = D.para_table()
    _, answer, spec = D.gen_para(rng)
    assert [t - V.WORD0 for t in answer] == [tbl[w] for w in spec["items"]]


# ---------------------------------------------------------------------------
# MRF toy
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1))
def test_mrf_sample_constraints(seed):
    rng = np.random.default_rng(seed)
    s = D.mrf_sample(rng, 16)
    assert s.shape == (16, 9)
    assert s.min() >= 0 and s.max() <= 2
    x, y = s[:, :5], s[:, 5:]
    np.testing.assert_array_equal((x[:, :4] + x[:, 1:]) % 3, y)


def test_mrf_ground_truth_graph():
    edges = D.mrf_true_edges()
    assert len(edges) == 12  # 4 triangles, edge (X_{i+1}, ...) shared? no:
    # triangles {0,1,5},{1,2,6},{2,3,7},{3,4,8} share only X-chain nodes
    deg = D.mrf_true_degrees()
    assert deg == [2, 4, 4, 4, 2, 2, 2, 2, 2]
    for a, b in edges:
        assert 0 <= a < b < 9


def test_vocab_names_unique():
    names = [V.token_name(t) for t in range(V.VOCAB_SIZE)]
    assert len(set(names)) == V.VOCAB_SIZE
    assert V.vocab_table()["<mask>"] == V.MASK
